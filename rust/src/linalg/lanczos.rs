//! Lanczos iteration with full reorthogonalization for extreme eigenvalues
//! of an implicitly-defined symmetric operator — the large-n path of the
//! OSE spectral check (DESIGN.md F-OSE): we need only λ_min / λ_max of
//! Z U ᵀ (K̃+λI) U Z, which is available as a mat-vec.

use super::{axpy, dot, norm2, sym_eig, Matrix};
use crate::util::rng::Pcg64;

/// Extreme-eigenvalue estimates.
#[derive(Debug, Clone)]
pub struct LanczosResult {
    pub min: f64,
    pub max: f64,
    pub iters: usize,
    /// All Ritz values (ascending) of the final Krylov subspace.
    pub ritz: Vec<f64>,
}

/// Run `k` Lanczos steps on the operator `op: v -> A v` (symmetric, n×n).
/// Full reorthogonalization (k is small: ≤ ~100) keeps the Ritz values
/// honest in f64.
pub fn lanczos_extreme<F>(n: usize, k: usize, seed: u64, mut op: F) -> LanczosResult
where
    F: FnMut(&[f64]) -> Vec<f64>,
{
    assert!(n > 0);
    let k = k.min(n);
    let mut rng = Pcg64::new(seed, 17);
    let mut q: Vec<Vec<f64>> = Vec::with_capacity(k);
    let mut v0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let nrm = norm2(&v0);
    v0.iter_mut().for_each(|x| *x /= nrm);
    q.push(v0);
    let mut alphas = Vec::with_capacity(k);
    let mut betas = Vec::with_capacity(k);
    for j in 0..k {
        let mut w = op(&q[j]);
        let alpha = dot(&q[j], &w);
        alphas.push(alpha);
        axpy(-alpha, &q[j], &mut w);
        if j > 0 {
            let b: f64 = betas[j - 1];
            axpy(-b, &q[j - 1], &mut w);
        }
        // full reorthogonalization (twice is enough)
        for _ in 0..2 {
            for qi in &q {
                let c = dot(qi, &w);
                axpy(-c, qi, &mut w);
            }
        }
        let beta = norm2(&w);
        if beta < 1e-13 || j + 1 == k {
            break;
        }
        betas.push(beta);
        w.iter_mut().for_each(|x| *x /= beta);
        q.push(w);
    }
    // tridiagonal Ritz problem
    let steps = alphas.len();
    let mut t = Matrix::zeros(steps, steps);
    for i in 0..steps {
        t[(i, i)] = alphas[i];
        if i + 1 < steps {
            t[(i, i + 1)] = betas[i];
            t[(i + 1, i)] = betas[i];
        }
    }
    let eig = sym_eig(&t);
    LanczosResult {
        min: *eig.values.first().unwrap(),
        max: *eig.values.last().unwrap(),
        iters: steps,
        ritz: eig.values,
    }
}

/// Result of a Gauss–Lanczos quadrature estimate of vᵀA⁻¹v.
#[derive(Debug, Clone)]
pub struct QuadformResult {
    /// The estimate of vᵀ A⁻¹ v.
    pub value: f64,
    /// Lanczos steps actually taken (early breakdown stops sooner).
    pub iters: usize,
}

/// Estimate the quadratic form vᵀ A⁻¹ v for an SPD operator `op: u → A u`
/// by Gauss–Lanczos quadrature (Golub & Meurant): `k` Lanczos steps
/// started from v/‖v‖ build the Jacobi matrix T_k, and
/// ‖v‖² · e₁ᵀ T_k⁻¹ e₁ is the k-point Gauss estimate of the Stieltjes
/// integral ∫ μ⁻¹ dω(μ). Deterministic — the start vector *is* v, no RNG
/// is drawn — and exact once k reaches the Krylov dimension of (A, v);
/// breakdown (invariant subspace found) stops early with the already-exact
/// estimate. For SPD A the estimate is a non-negative quadratic form.
pub fn lanczos_quadform_inv<F>(n: usize, k: usize, v: &[f64], mut op: F) -> QuadformResult
where
    F: FnMut(&[f64]) -> Vec<f64>,
{
    assert_eq!(v.len(), n, "probe vector must match the operator size");
    let k = k.min(n).max(1);
    let vnorm2: f64 = dot(v, v);
    if vnorm2 == 0.0 {
        return QuadformResult { value: 0.0, iters: 0 };
    }
    let nrm = vnorm2.sqrt();
    let mut q: Vec<Vec<f64>> = Vec::with_capacity(k);
    q.push(v.iter().map(|x| x / nrm).collect());
    let mut alphas = Vec::with_capacity(k);
    let mut betas = Vec::with_capacity(k);
    for j in 0..k {
        let mut w = op(&q[j]);
        let alpha = dot(&q[j], &w);
        alphas.push(alpha);
        axpy(-alpha, &q[j], &mut w);
        if j > 0 {
            let b: f64 = betas[j - 1];
            axpy(-b, &q[j - 1], &mut w);
        }
        // full reorthogonalization (twice is enough)
        for _ in 0..2 {
            for qi in &q {
                let c = dot(qi, &w);
                axpy(-c, qi, &mut w);
            }
        }
        let beta = norm2(&w);
        if beta < 1e-13 || j + 1 == k {
            break;
        }
        betas.push(beta);
        w.iter_mut().for_each(|x| *x /= beta);
        q.push(w);
    }
    // e₁ᵀ T⁻¹ e₁ through the spectral decomposition of the small Jacobi
    // matrix: Σ_j U₁ⱼ² / θ_j (θ_j the Ritz values, all > 0 for SPD A).
    let steps = alphas.len();
    let mut t = Matrix::zeros(steps, steps);
    for i in 0..steps {
        t[(i, i)] = alphas[i];
        if i + 1 < steps {
            t[(i, i + 1)] = betas[i];
            t[(i + 1, i)] = betas[i];
        }
    }
    let eig = sym_eig(&t);
    let mut e1_t_inv_e1 = 0.0f64;
    for j in 0..steps {
        let u1j = eig.vectors[(0, j)];
        e1_t_inv_e1 += u1j * u1j / eig.values[j];
    }
    QuadformResult { value: vnorm2 * e1_t_inv_e1, iters: steps }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_extremes_of_diagonal() {
        let n = 200;
        let diag: Vec<f64> = (0..n).map(|i| 1.0 + i as f64 / 10.0).collect();
        let d = diag.clone();
        let res = lanczos_extreme(n, 60, 1, move |v| {
            v.iter().zip(&d).map(|(x, di)| x * di).collect()
        });
        assert!((res.max - diag[n - 1]).abs() < 1e-6, "max {}", res.max);
        assert!((res.min - diag[0]).abs() < 1e-3, "min {}", res.min);
    }

    #[test]
    fn matches_dense_eig_on_random_spd() {
        let mut rng = Pcg64::new(4, 0);
        let b = Matrix::random_normal(&mut rng, 60, 60);
        let mut a = b.matmul(&b.transpose());
        a.add_diag(1.0);
        a.symmetrize();
        let dense = sym_eig(&a);
        let a2 = a.clone();
        let res = lanczos_extreme(60, 60, 2, move |v| a2.matvec(v));
        assert!((res.max - dense.values[59]).abs() < 1e-6 * dense.values[59]);
        assert!((res.min - dense.values[0]).abs() < 1e-4 * dense.values[59]);
    }

    #[test]
    fn quadform_exact_on_diagonal() {
        // A = diag(d): vᵀA⁻¹v = Σ v_i²/d_i, reached exactly once the
        // Krylov space saturates.
        let n = 40;
        let diag: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64) * 0.25).collect();
        let v: Vec<f64> = (0..n).map(|i| ((i * 7 % 11) as f64) - 5.0).collect();
        let want: f64 = v.iter().zip(&diag).map(|(x, d)| x * x / d).sum();
        let d = diag.clone();
        let res = lanczos_quadform_inv(n, n, &v, move |u| {
            u.iter().zip(&d).map(|(x, di)| x * di).collect()
        });
        assert!(
            (res.value - want).abs() < 1e-8 * want,
            "{} vs {want}",
            res.value
        );
    }

    #[test]
    fn quadform_matches_dense_solve_on_random_spd() {
        let mut rng = Pcg64::new(9, 0);
        let b = Matrix::random_normal(&mut rng, 30, 30);
        let mut a = b.matmul(&b.transpose());
        a.add_diag(2.0);
        a.symmetrize();
        let v: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        // dense reference via eigendecomposition
        let eig = sym_eig(&a);
        let mut want = 0.0;
        for j in 0..30 {
            let uj: f64 = (0..30).map(|i| eig.vectors[(i, j)] * v[i]).sum();
            want += uj * uj / eig.values[j];
        }
        let a2 = a.clone();
        let res = lanczos_quadform_inv(30, 30, &v, move |u| a2.matvec(u));
        assert!(
            (res.value - want).abs() < 1e-7 * (1.0 + want.abs()),
            "{} vs {want}",
            res.value
        );
        assert!(res.value >= 0.0);
    }

    #[test]
    fn quadform_truncated_rank_is_nonnegative_and_close() {
        let mut rng = Pcg64::new(10, 0);
        let b = Matrix::random_normal(&mut rng, 50, 50);
        let mut a = b.matmul(&b.transpose());
        a.add_diag(5.0);
        a.symmetrize();
        let v: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let a2 = a.clone();
        let full = lanczos_quadform_inv(50, 50, &v, |u| a2.matvec(u));
        let a3 = a.clone();
        let low = lanczos_quadform_inv(50, 12, &v, |u| a3.matvec(u));
        assert!(low.value >= 0.0);
        assert!(low.iters <= 12);
        assert!(
            (low.value - full.value).abs() < 0.05 * full.value.abs().max(1e-12),
            "rank-12 {} vs full {}",
            low.value,
            full.value
        );
    }

    #[test]
    fn quadform_zero_vector_is_zero() {
        let res = lanczos_quadform_inv(8, 8, &[0.0; 8], |u| u.to_vec());
        assert_eq!(res.value, 0.0);
        assert_eq!(res.iters, 0);
    }

    #[test]
    fn early_breakdown_on_low_rank() {
        // rank-1 operator: Lanczos must stop early without NaNs
        let u: Vec<f64> = (0..50).map(|i| (i as f64 * 0.3).sin()).collect();
        let un = norm2(&u);
        let u2: Vec<f64> = u.iter().map(|x| x / un).collect();
        let res = lanczos_extreme(50, 30, 3, move |v| {
            let c = dot(&u2, v);
            u2.iter().map(|x| c * x).collect()
        });
        assert!(res.iters <= 3);
        assert!((res.max - 1.0).abs() < 1e-8);
    }
}
