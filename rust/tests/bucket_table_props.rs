//! Property tests for `lsh::BucketTable` (the "lists L_j" structure of
//! paper §4), driven by the `util::prop` harness: dense renumbering,
//! lookup consistency, bucket accounting, and the exact memory formula.

use std::collections::HashMap;

use wlsh_krr::lsh::BucketTable;
use wlsh_krr::util::prop::{gens, prop_check};
use wlsh_krr::util::rng::Pcg64;

/// Random id vector with a controlled number of distinct raw ids, plus
/// some sparse large ids to exercise the hash map (not just small keys).
fn gen_ids(rng: &mut Pcg64) -> Vec<u64> {
    let n = gens::size(rng, 1, 400);
    let universe = gens::size(rng, 1, 64) as u64;
    (0..n)
        .map(|_| {
            if rng.uniform() < 0.1 {
                // occasional far-flung raw id (mimics the u64 mix output)
                rng.next_u64() | (1 << 63)
            } else {
                rng.below(universe)
            }
        })
        .collect()
}

#[test]
fn prop_lookup_is_consistent_with_bucket_of() {
    prop_check(1, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        for (i, &id) in ids.iter().enumerate() {
            match t.lookup(id) {
                Some(b) if b == t.bucket_of[i] => {}
                other => {
                    return Err(format!(
                        "lookup({id}) = {other:?} but bucket_of[{i}] = {}",
                        t.bucket_of[i]
                    ))
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lookup_misses_absent_ids() {
    prop_check(2, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        // find an id that is definitely not present
        let absent = (0u64..).find(|c| !ids.contains(c)).unwrap();
        if t.lookup(absent).is_some() {
            return Err(format!("lookup({absent}) hit an absent id"));
        }
        Ok(())
    });
}

#[test]
fn prop_n_buckets_equals_distinct_ids_and_ids_share_buckets_iff_equal() {
    prop_check(3, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        let mut first_seen: HashMap<u64, u32> = HashMap::new();
        for (i, &id) in ids.iter().enumerate() {
            let b = *first_seen.entry(id).or_insert(t.bucket_of[i]);
            if t.bucket_of[i] != b {
                return Err(format!("id {id} got two buckets: {} and {b}", t.bucket_of[i]));
            }
        }
        if t.n_buckets != first_seen.len() {
            return Err(format!(
                "n_buckets {} != distinct ids {}",
                t.n_buckets,
                first_seen.len()
            ));
        }
        // dense: every index below n_buckets, assigned in first-appearance order
        let mut expected_next = 0u32;
        for (i, &id) in ids.iter().enumerate() {
            if ids[..i].iter().all(|&p| p != id) {
                if t.bucket_of[i] != expected_next {
                    return Err(format!(
                        "first occurrence of {id} got bucket {} (want {expected_next})",
                        t.bucket_of[i]
                    ));
                }
                expected_next += 1;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sizes_histogram_accounts_for_every_point() {
    prop_check(4, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        let sizes = t.sizes();
        if sizes.len() != t.n_buckets {
            return Err(format!("sizes len {} != n_buckets {}", sizes.len(), t.n_buckets));
        }
        if sizes.iter().any(|&s| s == 0) {
            return Err("empty bucket in histogram".into());
        }
        let total: u32 = sizes.iter().sum();
        if total as usize != ids.len() {
            return Err(format!("sizes sum {total} != n {}", ids.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_memory_accounting_matches_structure() {
    // Lemma 27: O(n) words. The estimate is exactly 4 bytes per point for
    // the dense index plus 16 per distinct bucket for the raw-id map.
    prop_check(5, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        let want = ids.len() * 4 + t.n_buckets * 16;
        if t.memory_bytes() != want {
            return Err(format!("memory_bytes {} != {want}", t.memory_bytes()));
        }
        Ok(())
    });
}
