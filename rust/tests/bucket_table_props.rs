//! Property tests for `lsh::BucketTable` (the "lists L_j" structure of
//! paper §4), driven by the `util::prop` harness: dense renumbering,
//! lookup consistency, bucket accounting, the exact memory formula, and
//! member-for-member equivalence of the flat CSR layout with a naive
//! per-bucket `Vec<Vec<u32>>` reference build.

use std::collections::HashMap;

use wlsh_krr::lsh::BucketTable;
use wlsh_krr::util::prop::{gens, prop_check};
use wlsh_krr::util::rng::Pcg64;

/// Random id vector with a controlled number of distinct raw ids, plus
/// some sparse large ids to exercise the hash map (not just small keys).
fn gen_ids(rng: &mut Pcg64) -> Vec<u64> {
    let n = gens::size(rng, 1, 400);
    let universe = gens::size(rng, 1, 64) as u64;
    (0..n)
        .map(|_| {
            if rng.uniform() < 0.1 {
                // occasional far-flung raw id (mimics the u64 mix output)
                rng.next_u64() | (1 << 63)
            } else {
                rng.below(universe)
            }
        })
        .collect()
}

#[test]
fn prop_lookup_is_consistent_with_bucket_of() {
    prop_check(1, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        for (i, &id) in ids.iter().enumerate() {
            match t.lookup(id) {
                Some(b) if b == t.bucket_of[i] => {}
                other => {
                    return Err(format!(
                        "lookup({id}) = {other:?} but bucket_of[{i}] = {}",
                        t.bucket_of[i]
                    ))
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lookup_misses_absent_ids() {
    prop_check(2, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        // find an id that is definitely not present
        let absent = (0u64..).find(|c| !ids.contains(c)).unwrap();
        if t.lookup(absent).is_some() {
            return Err(format!("lookup({absent}) hit an absent id"));
        }
        Ok(())
    });
}

#[test]
fn prop_n_buckets_equals_distinct_ids_and_ids_share_buckets_iff_equal() {
    prop_check(3, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        let mut first_seen: HashMap<u64, u32> = HashMap::new();
        for (i, &id) in ids.iter().enumerate() {
            let b = *first_seen.entry(id).or_insert(t.bucket_of[i]);
            if t.bucket_of[i] != b {
                return Err(format!("id {id} got two buckets: {} and {b}", t.bucket_of[i]));
            }
        }
        if t.n_buckets != first_seen.len() {
            return Err(format!(
                "n_buckets {} != distinct ids {}",
                t.n_buckets,
                first_seen.len()
            ));
        }
        // dense: every index below n_buckets, assigned in first-appearance order
        let mut expected_next = 0u32;
        for (i, &id) in ids.iter().enumerate() {
            if ids[..i].iter().all(|&p| p != id) {
                if t.bucket_of[i] != expected_next {
                    return Err(format!(
                        "first occurrence of {id} got bucket {} (want {expected_next})",
                        t.bucket_of[i]
                    ));
                }
                expected_next += 1;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sizes_histogram_accounts_for_every_point() {
    prop_check(4, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        let sizes = t.sizes();
        if sizes.len() != t.n_buckets {
            return Err(format!("sizes len {} != n_buckets {}", sizes.len(), t.n_buckets));
        }
        if sizes.iter().any(|&s| s == 0) {
            return Err("empty bucket in histogram".into());
        }
        let total: u32 = sizes.iter().sum();
        if total as usize != ids.len() {
            return Err(format!("sizes sum {total} != n {}", ids.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_memory_accounting_matches_structure() {
    // Lemma 27: O(n) words. The estimate is exactly 4 bytes per point for
    // the dense index, 4 per point for the CSR members, 4 per CSR offset
    // (n_buckets + 1 of them), plus 16 per distinct bucket for the raw-id
    // map.
    prop_check(5, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        let want = ids.len() * 8 + (t.n_buckets + 1) * 4 + t.n_buckets * 16;
        if t.memory_bytes() != want {
            return Err(format!("memory_bytes {} != {want}", t.memory_bytes()));
        }
        Ok(())
    });
}

/// Naive reference build of the inverted lists: push each point into its
/// bucket's `Vec` in point order (the layout the CSR arrays replace).
fn naive_bucket_lists(ids: &[u64]) -> Vec<Vec<u32>> {
    let mut dense: HashMap<u64, usize> = HashMap::new();
    let mut lists: Vec<Vec<u32>> = Vec::new();
    for (i, &id) in ids.iter().enumerate() {
        let next = lists.len();
        let b = *dense.entry(id).or_insert(next);
        if b == lists.len() {
            lists.push(Vec::new());
        }
        lists[b].push(i as u32);
    }
    lists
}

#[test]
fn prop_csr_is_member_for_member_identical_to_naive_reference() {
    prop_check(6, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        let reference = naive_bucket_lists(ids);
        if t.n_buckets != reference.len() {
            return Err(format!(
                "n_buckets {} != reference {}",
                t.n_buckets,
                reference.len()
            ));
        }
        if t.offsets.first() != Some(&0) {
            return Err(format!("offsets[0] = {:?}", t.offsets.first()));
        }
        if *t.offsets.last().unwrap() as usize != ids.len() {
            return Err(format!(
                "offsets[last] {} != n {}",
                t.offsets.last().unwrap(),
                ids.len()
            ));
        }
        for (j, want) in reference.iter().enumerate() {
            let got = t.bucket_members(j);
            if got != want.as_slice() {
                return Err(format!("bucket {j}: CSR {got:?} != reference {want:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_csr_offsets_are_monotone_and_match_sizes() {
    prop_check(7, 60, gen_ids, |ids| {
        let t = BucketTable::build(ids);
        if t.offsets.len() != t.n_buckets + 1 {
            return Err(format!(
                "offsets len {} != n_buckets + 1 = {}",
                t.offsets.len(),
                t.n_buckets + 1
            ));
        }
        for w in t.offsets.windows(2) {
            if w[0] > w[1] {
                return Err(format!("offsets not monotone: {} > {}", w[0], w[1]));
            }
        }
        let sizes = t.sizes();
        for (j, &s) in sizes.iter().enumerate() {
            if t.offsets[j + 1] - t.offsets[j] != s {
                return Err(format!("bucket {j}: offset span != size {s}"));
            }
        }
        Ok(())
    });
}
