//! The sharded solve's correctness contract: for every shard count and
//! every worker-thread count, the distributed CG produces a β (and
//! predictions) **bit-identical** to the single-process solve — raw
//! block partials reduced in global block order, normalized once. And
//! its failure contract: a dead or unreachable shard surfaces as a
//! typed [`KrrError::Shard`] within the connection timeout — no hang,
//! no partial result.
//!
//! Workers run two ways here: in-thread (`run_worker` on a std thread,
//! addressed through a `remote(...)` topology — fast, no process spawn)
//! and as real `wlsh-krr shard-worker` child processes (the
//! `shards(n=N)` local-spawn path and the kill tests).

use std::io::{BufRead, BufReader};
use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use wlsh_krr::api::{KrrError, MethodSpec, TopologySpec};
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::{run_worker, ShardedOperator, Trainer};
use wlsh_krr::data::{synthetic_by_name, Dataset};
use wlsh_krr::sketch::KrrOperator;

fn dataset() -> (Dataset, Dataset) {
    let mut ds = synthetic_by_name("wine", Some(240), 11).expect("dataset");
    ds.standardize();
    ds.split(180, 11)
}

fn config(workers: usize) -> KrrConfig {
    KrrConfig {
        method: MethodSpec::Wlsh,
        budget: 24, // 3 FUSE_BLOCKs: a 4-shard plan includes an empty shard
        scale: 3.0,
        lambda: 0.5,
        seed: 11,
        workers,
        ..Default::default()
    }
}

/// Start `n` in-thread shard workers on ephemeral ports; returns their
/// addresses in shard order. The threads serve until process exit.
fn spawn_thread_workers(n: usize) -> Vec<String> {
    let (tx, rx) = mpsc::channel();
    for _ in 0..n {
        let tx = tx.clone();
        std::thread::spawn(move || run_worker("127.0.0.1:0", Some(tx)).unwrap());
    }
    (0..n).map(|_| rx.recv().expect("worker announced its address")).collect()
}

/// Spawn a real `shard-worker` child process and scrape its address.
fn spawn_process_worker() -> (Child, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_wlsh-krr"))
        .args(["shard-worker", "--addr", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn wlsh-krr shard-worker");
    let stdout = child.stdout.take().unwrap();
    let mut reader = BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read worker stdout");
        assert!(n > 0, "worker exited before announcing its address");
        if let Some(rest) = line.trim_end().strip_prefix("shard listening on ") {
            break rest.to_string();
        }
    };
    (child, addr)
}

#[test]
fn sharded_beta_and_predictions_match_single_process_bit_for_bit() {
    let (tr, te) = dataset();
    for workers in [1usize, 2] {
        let reference = Trainer::new(config(workers)).train(&tr).expect("local train");
        let want_beta = reference.beta.clone();
        let want_pred = reference.predict(&te.x);
        for shards in [1usize, 2, 4] {
            let mut cfg = config(workers);
            cfg.topology = TopologySpec::Remote { addrs: spawn_thread_workers(shards) };
            let model = Trainer::new(cfg).train(&tr).expect("sharded train");
            assert_eq!(
                model.beta, want_beta,
                "beta diverged at shards={shards} workers={workers}"
            );
            // predictions fan out through the sharded predictor; must
            // also be exact (read before the next train rebuilds state)
            let pred = model.predict(&te.x);
            assert_eq!(
                pred, want_pred,
                "predictions diverged at shards={shards} workers={workers}"
            );
        }
    }
}

#[test]
fn locally_spawned_shard_processes_reproduce_the_local_beta() {
    // tests run from a harness binary in target/*/deps; point the
    // spawner at the real CLI binary cargo built for us
    std::env::set_var("WLSH_SHARD_BIN", env!("CARGO_BIN_EXE_wlsh-krr"));
    let (tr, te) = dataset();
    let reference = Trainer::new(config(1)).train(&tr).expect("local train");
    let mut cfg = config(1);
    cfg.topology = TopologySpec::Shards { n: 2 };
    let model = Trainer::new(cfg).train(&tr).expect("process-sharded train");
    assert_eq!(model.beta, reference.beta, "beta diverged across processes");
    let nq = te.d * 8;
    assert_eq!(
        model.predict(&te.x[..nq]),
        reference.predict(&te.x[..nq]),
        "predictions diverged across processes"
    );
    // model drop tears the worker processes down here
}

#[test]
fn killed_shard_latches_a_typed_error_without_hanging() {
    let (tr, _) = dataset();
    let (mut child0, addr0) = spawn_process_worker();
    let (mut child1, addr1) = spawn_process_worker();
    let mut cfg = config(1);
    cfg.topology = TopologySpec::Remote { addrs: vec![addr0, addr1.clone()] };
    let op = ShardedOperator::build(&cfg, &tr.x, tr.n, tr.d).expect("sharded build");

    // healthy: a mat-vec against both shards produces real numbers
    let beta = vec![1.0f64; tr.n];
    let y = op.matvec(&beta);
    assert!(y.iter().any(|v| *v != 0.0), "healthy matvec returned zeros");
    assert!(op.failure().is_none());

    // kill shard 1 and mat-vec again: the failure must latch within the
    // read budget (a dead peer resets the socket — this takes
    // microseconds, not the 120s wedge timeout), naming the shard
    child1.kill().expect("kill shard 1");
    child1.wait().expect("reap shard 1");
    let t0 = Instant::now();
    let y2 = op.matvec(&beta);
    let elapsed = t0.elapsed();
    assert!(y2.iter().all(|v| *v == 0.0), "failed matvec must not return partials");
    match op.failure() {
        Some(KrrError::Shard(msg)) => {
            assert!(msg.contains(&addr1), "error names the wrong shard: {msg}")
        }
        other => panic!("expected a latched KrrError::Shard, got {other:?}"),
    }
    assert!(elapsed < Duration::from_secs(10), "failure took {elapsed:?} to surface");

    // latched: subsequent mat-vecs short-circuit instantly
    let t1 = Instant::now();
    let y3 = op.matvec(&beta);
    assert!(y3.iter().all(|v| *v == 0.0));
    assert!(t1.elapsed() < Duration::from_secs(1));

    drop(op);
    // shard 0 is a remote worker (not ours to stop); reap it explicitly
    child0.kill().ok();
    child0.wait().ok();
}

#[test]
fn unreachable_shard_fails_the_train_quickly_with_a_typed_error() {
    // an address nothing listens on: bind, read the port, drop the
    // listener. Shrink the connect budget so the test stays fast.
    std::env::set_var("WLSH_SHARD_CONNECT_MS", "500");
    let port = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let (tr, _) = dataset();
    let mut cfg = config(1);
    cfg.topology = TopologySpec::Remote { addrs: vec![format!("127.0.0.1:{port}")] };
    let t0 = Instant::now();
    let res = Trainer::new(cfg).train(&tr);
    let elapsed = t0.elapsed();
    std::env::remove_var("WLSH_SHARD_CONNECT_MS");
    match res {
        Err(KrrError::Shard(msg)) => assert!(msg.contains("connect"), "{msg}"),
        other => panic!("expected KrrError::Shard, got {:?}", other.map(|m| m.report)),
    }
    assert!(elapsed < Duration::from_secs(30), "dead-shard train took {elapsed:?}");
}
