//! Scalar-vs-SIMD equivalence: flipping `WLSH_SIMD` must change
//! throughput, never numbers. Build paths (instance tables, bucket loads,
//! mat-vecs, CG β) are asserted **bit-identical** across
//! `WLSH_SIMD=on|off` × worker counts {1, 2, 8}, and the f32 serving
//! paths (dense + CSR predictions, RFF features) carry a documented ULP
//! tolerance of **0** — every `util::simd` kernel reproduces its scalar
//! reference exactly (fixed-order reductions, no FMA, a shared
//! deterministic cosine), so these tests use exact equality throughout,
//! mirroring the `stream_equivalence.rs` harness.
//!
//! The dispatch state is process-global (`util::simd::set_enabled`), so
//! every test serializes on one lock and restores auto-detection on exit.
//! On hardware with no SIMD path the two settings coincide and the
//! assertions hold trivially.

use std::sync::Mutex;

use wlsh_krr::api::MethodSpec;
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::Trainer;
use wlsh_krr::data::{synthetic_by_name, Dataset, SparseChunk};
use wlsh_krr::sketch::{KrrOperator, RffSketch, WlshBuildParams, WlshSketch};
use wlsh_krr::util::rng::Pcg64;
use wlsh_krr::util::simd;

const THREADS: [usize; 3] = [1, 2, 8];

static SIMD_LOCK: Mutex<()> = Mutex::new(());

/// Restores auto-detection even if the test panics mid-flight.
struct SimdGuard;

impl Drop for SimdGuard {
    fn drop(&mut self) {
        simd::reset();
    }
}

fn standardized_wine(n: usize) -> Dataset {
    let mut ds = synthetic_by_name("wine", Some(n), 11).unwrap();
    ds.standardize();
    ds
}

fn random_beta(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::new(seed, 0);
    (0..n).map(|_| rng.normal()).collect()
}

/// CSR image of dense row-major data, dropping exact zeros (the loaders'
/// canonical form: ascending unique indices per row).
fn to_csr(x: &[f32], d: usize) -> (Vec<usize>, Vec<u32>, Vec<f32>) {
    let mut indptr = vec![0usize];
    let mut indices = Vec::new();
    let mut values = Vec::new();
    for row in x.chunks(d) {
        for (j, &v) in row.iter().enumerate() {
            if v != 0.0 {
                indices.push(j as u32);
                values.push(v);
            }
        }
        indptr.push(indices.len());
    }
    (indptr, indices, values)
}

#[test]
fn wlsh_build_solve_and_matvec_bit_identical_across_simd_and_threads() {
    let _lock = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _guard = SimdGuard;
    let ds = standardized_wine(200);
    let beta = random_beta(ds.n, 3);
    let queries = &ds.x[..40 * ds.d];
    for (bucket_s, shape) in [("rect", 2.0), ("smooth2", 7.0)] {
        let params = WlshBuildParams::new(ds.n, ds.d, 16)
            .bucket_str(bucket_s)
            .gamma_shape(shape)
            .scale(3.0)
            .seed(5);
        simd::set_enabled(false);
        let base = WlshSketch::build_mem(&ds.x, &params);
        let base_mv: Vec<Vec<f64>> =
            THREADS.iter().map(|&t| base.matvec_threads(&beta, t)).collect();
        let base_pred = base.predict(queries, &beta);
        let base_diag = base.diag_values();
        simd::set_enabled(true);
        // same sketch, SIMD kernels: bucket loads, fused mat-vec, serving
        for (&t, want) in THREADS.iter().zip(&base_mv) {
            assert_eq!(&base.matvec_threads(&beta, t), want, "{bucket_s} matvec t={t}");
        }
        assert_eq!(base.predict(queries, &beta), base_pred, "{bucket_s} predict");
        assert_eq!(base.diag_values(), base_diag, "{bucket_s} diag");
        // rebuilt sketch, SIMD hash path: tables and weights bit-equal
        let built = WlshSketch::build_mem(&ds.x, &params);
        for (a, b) in base.instances.iter().zip(&built.instances) {
            assert_eq!(a.table.bucket_of, b.table.bucket_of, "{bucket_s} bucket_of");
            assert_eq!(a.table.offsets, b.table.offsets, "{bucket_s} offsets");
            assert_eq!(a.table.members, b.table.members, "{bucket_s} members");
            assert_eq!(a.weights, b.weights, "{bucket_s} weights");
            assert_eq!(a.weights_csr, b.weights_csr, "{bucket_s} weights_csr");
        }
    }
}

#[test]
fn cg_coefficients_bit_identical_across_simd_for_every_method() {
    let _lock = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _guard = SimdGuard;
    let ds = standardized_wine(160);
    for method in [MethodSpec::Wlsh, MethodSpec::Rff] {
        for workers in [1usize, 2, 8] {
            let cfg = KrrConfig {
                method,
                budget: 24,
                scale: 3.0,
                lambda: 0.4,
                cg_max_iters: 60,
                workers,
                ..Default::default()
            };
            simd::set_enabled(false);
            let want = Trainer::new(cfg.clone()).train(&ds).unwrap();
            simd::set_enabled(true);
            let got = Trainer::new(cfg).train(&ds).unwrap();
            let tag = format!("{method} workers={workers}");
            assert_eq!(got.beta, want.beta, "{tag} β");
            assert_eq!(got.report.cg_iters, want.report.cg_iters, "{tag} iters");
            let q = &ds.x[..20 * ds.d];
            assert_eq!(got.predict(q), want.predict(q), "{tag} predict");
        }
    }
}

#[test]
fn rff_features_theta_and_sparse_path_bit_identical_across_simd() {
    let _lock = SIMD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _guard = SimdGuard;
    let ds = standardized_wine(200);
    let beta = random_beta(ds.n, 4);
    let queries = &ds.x[..40 * ds.d];
    // sparsify a query block so the CSR featurize path has zeros to skip
    let mut qs = queries.to_vec();
    for (k, v) in qs.iter_mut().enumerate() {
        if (k * 31 + 7) % 10 < 5 {
            *v = 0.0;
        }
    }
    let (indptr, indices, values) = to_csr(&qs, ds.d);
    let csr = SparseChunk { indptr: &indptr, indices: &indices, values: &values };

    simd::set_enabled(false);
    let base = RffSketch::build(&ds.x, ds.n, ds.d, 64, 3.0, 7);
    let base_feats = base.features().to_vec();
    let base_q = base.featurize(&qs);
    let base_sq = base.featurize_sparse(&csr);
    let base_theta = base.theta(&beta);
    let base_mv = base.matvec(&beta);
    let base_pred = base.predict(queries, &beta);

    simd::set_enabled(true);
    let built = RffSketch::build(&ds.x, ds.n, ds.d, 64, 3.0, 7);
    assert_eq!(built.features(), &base_feats[..], "feature matrix");
    assert_eq!(base.featurize(&qs), base_q, "dense featurize");
    assert_eq!(base.featurize_sparse(&csr), base_sq, "sparse featurize");
    assert_eq!(base_q, {
        // dense-vs-sparse stays exact under SIMD too
        base.featurize_sparse(&csr)
    });
    assert_eq!(base.theta(&beta), base_theta, "theta");
    assert_eq!(base.matvec(&beta), base_mv, "matvec");
    assert_eq!(base.predict(queries, &beta), base_pred, "predict (0-ULP serving bound)");
}
