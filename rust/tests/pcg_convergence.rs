//! Preconditioned-CG acceptance tests: on ill-conditioned synthetic
//! problems, `solve_krr_pcg` with each preconditioner must converge to the
//! unpreconditioned/direct solution within tolerance in fewer (or equal)
//! outer iterations than plain CG.

use std::sync::Arc;

use wlsh_krr::kernels::Kernel;
use wlsh_krr::linalg::Matrix;
use wlsh_krr::sketch::{ExactKernelOp, KrrOperator, NystromSketch, Predictor};
use wlsh_krr::solver::{
    materialize, solve_krr, solve_krr_direct, solve_krr_pcg, CgOptions, Preconditioner,
};
use wlsh_krr::util::rng::Pcg64;

/// Materialized-matrix operator (test-only): lets the tests build
/// arbitrarily conditioned SPD systems.
struct DenseOp {
    k: Matrix,
}

impl KrrOperator for DenseOp {
    fn n(&self) -> usize {
        self.k.rows
    }

    fn matvec(&self, beta: &[f64]) -> Vec<f64> {
        self.k.matvec(beta)
    }

    fn predict(&self, _queries: &[f32], _beta: &[f64]) -> Vec<f64> {
        unimplemented!("test operator has no out-of-sample extension")
    }

    fn predictor(self: Arc<Self>, _beta: &[f64]) -> Box<dyn Predictor> {
        unimplemented!("test operator has no out-of-sample extension")
    }

    fn diag(&self) -> Option<Vec<f64>> {
        Some((0..self.k.rows).map(|i| self.k[(i, i)]).collect())
    }

    fn name(&self) -> String {
        "dense-test".into()
    }

    fn memory_bytes(&self) -> usize {
        self.k.data.len() * 8
    }
}

fn toy_problem(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f64>) {
    let mut rng = Pcg64::new(seed, 0);
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    (x, y)
}

#[test]
fn jacobi_pcg_beats_plain_cg_on_diagonally_skewed_system() {
    // K = D K0 D with D spread over three orders of magnitude: the scaling
    // inflates the condition number by up to ~1e6, which is exactly the
    // structure a Jacobi preconditioner removes.
    let (n, d) = (120, 2);
    let (x, y) = toy_problem(n, d, 11);
    let base = ExactKernelOp::new(&x, n, d, Kernel::laplace(0.5));
    let mut k = materialize(&base);
    let scale: Vec<f64> = (0..n)
        .map(|i| 10f64.powf(1.5 * (2.0 * i as f64 / (n - 1) as f64 - 1.0)))
        .collect();
    for i in 0..n {
        for j in 0..n {
            k[(i, j)] *= scale[i] * scale[j];
        }
    }
    let op = DenseOp { k: k.clone() };
    let lambda = 1e-3;
    let opts = CgOptions { max_iters: 4000, tol: 1e-8, verbose: false, x0: None };

    let plain = solve_krr(&op, &y, lambda, &opts);
    let pre = Preconditioner::jacobi(&op.diag().unwrap(), lambda);
    let jac = solve_krr_pcg(&op, &y, lambda, &opts, &pre);

    assert!(jac.converged, "jacobi PCG failed to converge");
    assert!(
        jac.iters < plain.iters,
        "jacobi {} iters vs plain {} — preconditioner ineffective",
        jac.iters,
        plain.iters
    );
    // ground truth: dense direct solve of the same shifted system
    let direct = solve_krr_direct(&k, &y, lambda).unwrap();
    for i in 0..n {
        assert!(
            (jac.beta[i] - direct[i]).abs() < 1e-3 * (1.0 + direct[i].abs()),
            "i={i}: jacobi {} vs direct {}",
            jac.beta[i],
            direct[i]
        );
    }
}

#[test]
fn nystrom_pcg_beats_plain_cg_on_small_lambda_kernel_system() {
    // Laplace kernel with small λ: the spectrum's heavy tail makes plain
    // CG grind; a rank-r Nyström preconditioner of the same kernel caps
    // the preconditioned condition number near (λ + ‖K − K̃_nys‖)/λ.
    let (n, d) = (150, 2);
    let (x, y) = toy_problem(n, d, 13);
    let kernel = Kernel::laplace(0.3);
    let op = ExactKernelOp::new(&x, n, d, kernel.clone());
    let lambda = 1e-3;
    let opts = CgOptions { max_iters: 2000, tol: 1e-8, verbose: false, x0: None };

    let plain = solve_krr(&op, &y, lambda, &opts);
    let nys = NystromSketch::build(&x, n, d, 100, kernel, 17).unwrap();
    let pre = Preconditioner::Nystrom(nys.ridge_precond(lambda).unwrap());
    let pcg = solve_krr_pcg(&op, &y, lambda, &opts, &pre);

    assert!(pcg.converged, "nystrom PCG failed to converge");
    assert!(
        pcg.iters * 2 <= plain.iters,
        "nystrom pcg {} iters vs plain {} — preconditioner ineffective",
        pcg.iters,
        plain.iters
    );
    let k = materialize(&op);
    let direct = solve_krr_direct(&k, &y, lambda).unwrap();
    for i in 0..n {
        assert!(
            (pcg.beta[i] - direct[i]).abs() < 1e-3 * (1.0 + direct[i].abs()),
            "i={i}: pcg {} vs direct {}",
            pcg.beta[i],
            direct[i]
        );
    }
}

#[test]
fn every_preconditioner_solves_the_same_wlsh_sketch_system() {
    // End-to-end over the paper's estimator: plain CG, Jacobi (from the
    // sketch diagonal), and Nyström PCG must all land on the same β of
    // (K̃ + λI)β = y.
    let (n, d, m) = (200, 3, 128);
    let (x, y) = toy_problem(n, d, 19);
    let sk = wlsh_krr::sketch::WlshSketch::build_mem(
        &x,
        &wlsh_krr::sketch::WlshBuildParams::new(n, d, m)
            .bucket_str("smooth2")
            .gamma_shape(7.0)
            .seed(20),
    );
    let lambda = 0.05;
    let opts = CgOptions { max_iters: 1000, tol: 1e-10, verbose: false, x0: None };
    let plain = solve_krr(&sk, &y, lambda, &opts);
    assert!(plain.converged);

    let jac_pre = Preconditioner::jacobi(&sk.diag().unwrap(), lambda);
    let jac = solve_krr_pcg(&sk, &y, lambda, &opts, &jac_pre);
    assert!(jac.converged);
    // on a well-scaled sketch Jacobi is ≈ scalar scaling: same ballpark
    assert!(jac.iters <= plain.iters * 2, "jacobi {} vs plain {}", jac.iters, plain.iters);

    let nys = NystromSketch::build(&x, n, d, 64, Kernel::wlsh("smooth2", 7.0, 1.0), 21).unwrap();
    let nys_pre = Preconditioner::Nystrom(nys.ridge_precond(lambda).unwrap());
    let pcg = solve_krr_pcg(&sk, &y, lambda, &opts, &nys_pre);
    assert!(pcg.converged);

    for i in 0..n {
        for (label, beta) in [("jacobi", &jac.beta), ("nystrom", &pcg.beta)] {
            assert!(
                (beta[i] - plain.beta[i]).abs() < 1e-5 * (1.0 + plain.beta[i].abs()),
                "{label} i={i}: {} vs {}",
                beta[i],
                plain.beta[i]
            );
        }
    }
}
