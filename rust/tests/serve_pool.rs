//! Worker-pool serving engine, end-to-end through the TCP server:
//! bit-identical predictions for every worker count / queue depth / batch
//! boundary / arrival order, lossless signal-driven shutdown, and atomic
//! hot-reload over a live connection.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use wlsh_krr::api::MethodSpec;
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::{
    checkpoint, serve, ModelRegistry, ServerConfig, ServerStats, Trainer, TrainedModel,
};
use wlsh_krr::data::{synthetic_by_name, Dataset};
use wlsh_krr::util::json::{Json, JsonWriter};

fn trained(budget: usize) -> (Arc<TrainedModel>, Dataset) {
    let mut ds = synthetic_by_name("wine", Some(150), 1).unwrap();
    ds.standardize();
    let (tr, te) = ds.split(120, 2);
    let cfg = KrrConfig {
        method: MethodSpec::Wlsh,
        budget,
        scale: 3.0,
        ..Default::default()
    };
    (Arc::new(Trainer::new(cfg).train(&tr).unwrap()), te)
}

fn start(
    registry: Arc<ModelRegistry>,
    cfg: ServerConfig,
) -> (String, std::thread::JoinHandle<Arc<ServerStats>>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || serve(registry, cfg, Some(tx)).unwrap());
    (rx.recv().unwrap(), handle)
}

/// One query row as a JSON array literal, with shortest-roundtrip floats
/// (the wire format recovers the exact f32s, so server-side predictions
/// are bit-identical to calling the model in-process).
fn row_json(queries: &[f32], d: usize, qi: usize) -> String {
    let feats: Vec<String> =
        queries[qi * d..(qi + 1) * d].iter().map(|v| format!("{v}")).collect();
    format!("[{}]", feats.join(","))
}

fn read_pred(reader: &mut BufReader<TcpStream>) -> f64 {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(&line)
        .unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"))
        .get("pred")
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("no pred in {line:?}"))
}

fn shutdown(addr: &str) {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("ok"), "{line}");
}

#[test]
fn predictions_bit_identical_across_workers_queue_depth_and_batching() {
    let (model, te) = trained(16);
    let d = te.d;
    let nq = te.n.min(48);
    let queries = &te.x[..nq * d];
    let want = model.predict(queries);
    // worker count × queue depth × batch bound × linger, all over the same
    // request set with mixed single/batch requests and shuffled arrival
    for (workers, depth, max_batch, linger_us) in [
        (1usize, 1024usize, 64usize, 200u64),
        (2, 3, 1, 0),
        (8, 1024, 4, 100),
        (4, 8, 64, 0),
    ] {
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_batch,
            linger: Duration::from_micros(linger_us),
            workers,
            queue_depth: depth,
        };
        let (addr, handle) = start(ModelRegistry::single(model.clone()), cfg);
        let got = Mutex::new(vec![f64::NAN; nq]);
        std::thread::scope(|scope| {
            for c in 0..3usize {
                let addr = addr.clone();
                let got = &got;
                scope.spawn(move || {
                    let mut conn = TcpStream::connect(&addr).unwrap();
                    conn.set_nodelay(true).ok();
                    let mut reader = BufReader::new(conn.try_clone().unwrap());
                    // this client's rows; one client sends in reverse so
                    // arrival order differs from index order
                    let mut mine: Vec<usize> = (0..nq).filter(|i| i % 3 == c).collect();
                    if c == 1 {
                        mine.reverse();
                    }
                    let mut k = 0;
                    let mut use_batch = false;
                    while k < mine.len() {
                        if !use_batch || k + 1 == mine.len() {
                            let qi = mine[k];
                            writeln!(conn, "{{\"features\": {}}}", row_json(queries, d, qi))
                                .unwrap();
                            got.lock().unwrap()[qi] = read_pred(&mut reader);
                            k += 1;
                        } else {
                            // batch requests may not exceed the server's
                            // max_batch row cap
                            let take = (mine.len() - k).min(4).min(max_batch);
                            let idxs: Vec<usize> = mine[k..k + take].to_vec();
                            let rows: Vec<String> =
                                idxs.iter().map(|&qi| row_json(queries, d, qi)).collect();
                            writeln!(conn, "{{\"batch\": [{}]}}", rows.join(",")).unwrap();
                            for &qi in &idxs {
                                got.lock().unwrap()[qi] = read_pred(&mut reader);
                            }
                            k += take;
                        }
                        use_batch = !use_batch;
                    }
                });
            }
        });
        let got = got.into_inner().unwrap();
        for i in 0..nq {
            assert!(
                got[i] == want[i],
                "workers={workers} depth={depth} max_batch={max_batch} row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
        shutdown(&addr);
        handle.join().unwrap();
    }
}

#[test]
fn shutdown_during_in_flight_requests_loses_no_replies() {
    let (model, te) = trained(8);
    let d = te.d;
    // linger 0 keeps the pipelined burst well inside the shutdown grace
    // window even on a loaded machine
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        linger: Duration::from_micros(0),
        ..Default::default()
    };
    let (addr, handle) = start(ModelRegistry::single(model), cfg);
    // client A pipelines a burst without reading any replies...
    let mut a = TcpStream::connect(&addr).unwrap();
    a.set_nodelay(true).ok();
    let mut ra = BufReader::new(a.try_clone().unwrap());
    const N: usize = 40;
    let mut burst = String::new();
    for i in 0..N {
        burst.push_str(&format!("{{\"features\": {}}}\n", row_json(&te.x, d, i % te.n)));
    }
    a.write_all(burst.as_bytes()).unwrap();
    // ...then a second client shuts the server down while A's requests are
    // still in flight. Two pipelined shutdowns in one write: idempotent.
    let mut b = TcpStream::connect(&addr).unwrap();
    b.set_nodelay(true).ok();
    let mut rb = BufReader::new(b.try_clone().unwrap());
    b.write_all(b"{\"cmd\": \"shutdown\"}\n{\"cmd\": \"shutdown\"}\n").unwrap();
    for k in 0..2 {
        let mut line = String::new();
        rb.read_line(&mut line).unwrap();
        assert!(line.contains("ok"), "shutdown reply {k}: {line:?}");
    }
    // every request A managed to send still gets its reply
    for i in 0..N {
        let mut line = String::new();
        ra.read_line(&mut line).unwrap();
        assert!(line.contains("pred"), "request {i} lost in shutdown: {line:?}");
    }
    drop(a);
    drop(b);
    let stats = handle.join().unwrap();
    assert_eq!(stats.served.get(), N as u64);
    assert_eq!(stats.rejected.get(), 0);
}

#[test]
fn reload_cmd_hot_swaps_checkpoints_without_dropping_the_connection() {
    let mut ds = synthetic_by_name("wine", Some(150), 1).unwrap();
    ds.standardize();
    let (tr, te) = ds.split(120, 2);
    let tr = Arc::new(tr);
    let mk = |budget: usize| {
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget,
            scale: 3.0,
            ..Default::default()
        };
        Trainer::new(cfg).train(&tr).unwrap()
    };
    let m1 = mk(8);
    let m2 = mk(32);
    let p2 = std::env::temp_dir().join("wlsh_serve_pool_v2.ckpt");
    checkpoint::save(&m2, &p2).unwrap();
    let q = &te.x[..te.d];
    let want1 = m1.predict(q)[0];
    let want2 = m2.predict(q)[0];
    assert!(want1 != want2, "budgets 8 vs 32 must disagree for this test to bite");
    let ltr = tr.clone();
    let registry = Arc::new(ModelRegistry::with_loader(Box::new(move |path: &str| {
        checkpoint::load(std::path::Path::new(path), &ltr).map(Arc::new)
    })));
    registry.insert("default", Arc::new(m1));
    let cfg = ServerConfig { addr: "127.0.0.1:0".into(), workers: 2, ..Default::default() };
    let (addr, handle) = start(registry, cfg);
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let features = row_json(&te.x, te.d, 0);
    writeln!(conn, "{{\"features\": {features}}}").unwrap();
    assert_eq!(read_pred(&mut reader), want1);
    // hot-reload "default" from the v2 checkpoint — same connection
    let req = JsonWriter::object()
        .field_str("cmd", "reload")
        .field_str("model", "default")
        .field_str("path", p2.to_str().unwrap())
        .finish();
    writeln!(conn, "{req}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("ok"), "reload failed: {line}");
    writeln!(conn, "{{\"features\": {features}}}").unwrap();
    assert_eq!(read_pred(&mut reader), want2);
    // a bad reload errors but the server keeps serving the current model
    let bad = JsonWriter::object()
        .field_str("cmd", "reload")
        .field_str("model", "default")
        .field_str("path", "/nonexistent/ckpt")
        .finish();
    writeln!(conn, "{bad}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    writeln!(conn, "{{\"features\": {features}}}").unwrap();
    assert_eq!(read_pred(&mut reader), want2);
    writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    handle.join().unwrap();
    std::fs::remove_file(&p2).ok();
}
