//! The parallel WLSH hot paths must be *bit-identical* to the serial
//! reference — across thread counts (1, 2, 8) and across repeated runs
//! with the same seed. This is the determinism contract that makes the
//! scoped-thread fan-out safe to put under CG (where any drift would
//! compound across iterations) and under the serving stack (where two
//! replicas must answer identically).

use std::sync::Arc;

use wlsh_krr::api::MethodSpec;
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::Trainer;
use wlsh_krr::data::synthetic_by_name;
use wlsh_krr::sketch::{KrrOperator, Predictor, WlshBuildParams, WlshSketch};
use wlsh_krr::util::rng::Pcg64;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn random_x(seed: u64, n: usize, d: usize) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 0);
    (0..n * d).map(|_| rng.normal() as f32).collect()
}

fn random_beta(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Pcg64::new(seed, 1);
    (0..n).map(|_| rng.normal()).collect()
}

/// m ≥ 64, and the shape clears both of the trait paths' serial gates
/// (n = 2048 ≥ PAR_MIN_ROWS = 256, n·m = 147,456 ≥ PAR_MIN_WORK =
/// 131,072), so `matvec`/`loads_all`/`predictor` really fan out — not just
/// the explicit `*_threads` calls. m = 72 also straddles the fused path's
/// 8-instance block boundary (9 blocks, one round), exercising the fixed
/// block-order reduction.
fn big_sketch(seed: u64) -> (Arc<WlshSketch>, Vec<f64>, Vec<f32>) {
    let (n, d, m) = (2048, 8, 72);
    let x = random_x(seed, n, d);
    let sk = Arc::new(WlshSketch::build_mem(
        &x,
        &WlshBuildParams::new(n, d, m)
            .bucket_str("smooth2")
            .gamma_shape(7.0)
            .scale(1.2)
            .seed(seed + 1),
    ));
    let beta = random_beta(seed + 2, n);
    let q = random_x(seed + 3, 700, d);
    (sk, beta, q)
}

#[test]
fn matvec_bit_identical_across_thread_counts() {
    let (sk, beta, _) = big_sketch(100);
    let want = sk.matvec_serial(&beta);
    for threads in THREAD_COUNTS {
        let got = sk.matvec_threads(&beta, threads);
        assert_eq!(got, want, "matvec diverged at threads={threads}");
    }
    // the trait path (auto thread count) must agree too
    assert_eq!(sk.matvec(&beta), want, "trait matvec diverged");
}

#[test]
fn unfused_matvec_bit_identical_across_thread_counts() {
    // the kept pre-fusion baseline honors the same determinism contract
    let (sk, beta, _) = big_sketch(600);
    let want = sk.matvec_unfused(&beta, 1);
    for threads in THREAD_COUNTS {
        assert_eq!(
            sk.matvec_unfused(&beta, threads),
            want,
            "unfused diverged at threads={threads}"
        );
    }
}

#[test]
fn matvec_bit_identical_across_repeated_runs() {
    for threads in THREAD_COUNTS {
        let (sk_a, beta_a, _) = big_sketch(200);
        let (sk_b, beta_b, _) = big_sketch(200);
        assert_eq!(beta_a, beta_b);
        let ya = sk_a.matvec_threads(&beta_a, threads);
        let yb = sk_b.matvec_threads(&beta_b, threads);
        assert_eq!(ya, yb, "repeated run diverged at threads={threads}");
    }
}

#[test]
fn prepared_loads_bit_identical_across_thread_counts() {
    let (sk, beta, _) = big_sketch(300);
    let want = sk.loads_all(&beta, 1);
    for threads in THREAD_COUNTS {
        assert_eq!(sk.loads_all(&beta, threads), want, "loads diverged at threads={threads}");
    }
}

#[test]
fn predict_bit_identical_across_thread_counts() {
    let (sk, beta, q) = big_sketch(400);
    let predictor = sk.clone().predictor(&beta);
    let want = predictor.predict_threads(&q, 1);
    for threads in THREAD_COUNTS {
        let got = predictor.predict_threads(&q, threads);
        assert_eq!(got, want, "predict diverged at threads={threads}");
    }
    // the trait predict, the Predictor::predict handle path, and the
    // allocation-free predict_into must all match the serial reference
    assert_eq!(sk.predict(&q, &beta), want);
    assert_eq!(Predictor::predict(&predictor, &q), want);
    let mut buf = vec![f64::NAN; want.len()];
    predictor.predict_into(&q, &mut buf);
    assert_eq!(buf, want);
}

#[test]
fn predict_bit_identical_across_repeated_runs() {
    for threads in THREAD_COUNTS {
        let (sk_a, beta_a, qa) = big_sketch(500);
        let (sk_b, beta_b, qb) = big_sketch(500);
        let pa = sk_a.predictor(&beta_a).predict_threads(&qa, threads);
        let pb = sk_b.predictor(&beta_b).predict_threads(&qb, threads);
        assert_eq!(pa, pb, "repeated predict diverged at threads={threads}");
    }
}

#[test]
fn trained_model_is_thread_count_invariant_end_to_end() {
    // Full pipeline: the CG solve consumes the parallel mat-vec, so any
    // nondeterminism would surface as different β. Train the same config
    // twice with different worker counts for the sketch build and compare
    // predictions exactly.
    let mut ds = synthetic_by_name("wine", Some(600), 9).unwrap();
    ds.standardize();
    let (tr, te) = ds.split(480, 10);
    // n = 480 training rows stays under PAR_MIN_ROWS, so the CG mat-vecs
    // here run serial by design (the threaded trait path is covered by the
    // big_sketch tests above); what this asserts is that the worker-sharded
    // sketch *build* is deterministic all the way through solve + predict.
    let mk = |workers: usize| {
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 300,
            scale: 3.0,
            lambda: 0.5,
            workers,
            ..Default::default()
        };
        Trainer::new(cfg).train(&tr).unwrap()
    };
    let a = mk(1);
    let b = mk(4);
    assert_eq!(a.beta, b.beta, "CG solutions diverged across worker counts");
    assert_eq!(a.predict(&te.x), b.predict(&te.x));
}
