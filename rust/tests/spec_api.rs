//! The typed-spec API contract:
//!
//! 1. `parse(display(spec)) == spec` for every value of all four spec
//!    enums (property-style over randomized parameters);
//! 2. unknown strings surface as `Err(KrrError::Unknown...)` — never a
//!    panic — from the builder, the TOML path, and the spec parsers
//!    themselves (the CLI path is covered in `cli_smoke.rs`).

use wlsh_krr::api::{
    BucketSpec, KernelFamily, KernelSpec, KrrError, KrrModel, MethodSpec, PrecondSpec,
    SamplingSpec,
};
use wlsh_krr::config::{Config, KrrConfig};
use wlsh_krr::util::prop::prop_check;
use wlsh_krr::util::rng::Pcg64;

fn roundtrip<T>(spec: &T)
where
    T: std::fmt::Display + std::fmt::Debug + std::str::FromStr<Err = KrrError> + PartialEq,
{
    let shown = spec.to_string();
    match shown.parse::<T>() {
        Ok(back) => assert!(
            &back == spec,
            "round-trip drift: {spec:?} -> {shown:?} -> {back:?}"
        ),
        Err(e) => panic!("display {shown:?} of {spec:?} failed to parse: {e}"),
    }
}

/// A "nice" positive f64 whose Display round-trips visibly (Rust's f64
/// Display always round-trips exactly; this just keeps the cases human).
fn pos_param(rng: &mut Pcg64) -> f64 {
    (rng.uniform_in(0.05, 50.0) * 1000.0).round() / 1000.0
}

fn random_bucket(rng: &mut Pcg64) -> BucketSpec {
    if rng.below(3) == 0 {
        BucketSpec::Rect
    } else {
        BucketSpec::Smooth(1 + rng.below(8) as usize)
    }
}

#[test]
fn method_specs_roundtrip() {
    for m in [
        MethodSpec::Wlsh,
        MethodSpec::Rff,
        MethodSpec::Exact(KernelFamily::Laplace),
        MethodSpec::Exact(KernelFamily::SquaredExp),
        MethodSpec::Exact(KernelFamily::Matern52),
        MethodSpec::Exact(KernelFamily::Wlsh),
        MethodSpec::Nystrom,
    ] {
        roundtrip(&m);
    }
}

#[test]
fn bucket_specs_roundtrip() {
    prop_check(41, 60, random_bucket, |b| {
        roundtrip(b);
        Ok(())
    });
}

#[test]
fn precond_specs_roundtrip() {
    prop_check(
        43,
        60,
        |rng| match rng.below(3) {
            0 => PrecondSpec::None,
            1 => PrecondSpec::Jacobi,
            _ => PrecondSpec::Nystrom { rank: 1 + rng.below(4096) as usize },
        },
        |p| {
            roundtrip(p);
            Ok(())
        },
    );
}

#[test]
fn kernel_specs_roundtrip() {
    prop_check(
        47,
        80,
        |rng| match rng.below(4) {
            0 => KernelSpec::Laplace { scale: pos_param(rng) },
            1 => KernelSpec::SquaredExp { scale: pos_param(rng) },
            2 => KernelSpec::Matern52 { scale: pos_param(rng) },
            _ => KernelSpec::Wlsh {
                bucket: random_bucket(rng),
                gamma_shape: pos_param(rng),
                scale: pos_param(rng),
            },
        },
        |k| {
            roundtrip(k);
            Ok(())
        },
    );
}

#[test]
fn sampling_specs_roundtrip() {
    prop_check(
        53,
        80,
        |rng| match rng.below(3) {
            0 => SamplingSpec::Uniform,
            1 => SamplingSpec::Stein,
            _ => SamplingSpec::Leverage {
                pilot: 1 + rng.below(512) as usize,
                keep: 1 + rng.below(4096) as usize,
            },
        },
        |s| {
            roundtrip(s);
            Ok(())
        },
    );
}

#[test]
fn sampling_grammar_rejects_malformed_strings() {
    // never a panic: every malformed form is a BadParam
    for bad in [
        "importance",
        "leverage",
        "leverage()",
        "leverage(pilot=16)",
        "leverage(keep=48)",
        "leverage(pilot=0,keep=48)",
        "leverage(pilot=16,keep=0)",
        "leverage(pilot=sixteen,keep=48)",
        "leverage(pilot=16,keep=48,extra=1)",
        "stein(rate=2)",
    ] {
        assert!(
            matches!(bad.parse::<SamplingSpec>(), Err(KrrError::BadParam(_))),
            "{bad:?} should be rejected"
        );
    }
    // the empty string is the uniform default (CLI flag omitted)
    assert_eq!("".parse::<SamplingSpec>(), Ok(SamplingSpec::Uniform));
}

#[test]
fn unknown_strings_error_per_grammar() {
    assert_eq!(
        "wlshh".parse::<MethodSpec>(),
        Err(KrrError::UnknownMethod("wlshh".into()))
    );
    assert_eq!(
        "round".parse::<BucketSpec>(),
        Err(KrrError::UnknownBucket("round".into()))
    );
    assert_eq!(
        "ssor".parse::<PrecondSpec>(),
        Err(KrrError::UnknownPrecond("ssor".into()))
    );
    assert_eq!(
        "cosine".parse::<KernelSpec>(),
        Err(KrrError::UnknownKernel("cosine".into()))
    );
}

#[test]
fn builder_surfaces_unknown_method_as_error() {
    let mut ds = wlsh_krr::data::synthetic_by_name("wine", Some(120), 1).unwrap();
    ds.standardize();
    let err = KrrModel::builder().method("wlshh").fit(&ds).unwrap_err();
    assert_eq!(err, KrrError::UnknownMethod("wlshh".into()));
    // and a good spec right after a typo still reports the first error
    let err = KrrModel::builder()
        .method("wlshh")
        .bucket("rect")
        .fit(&ds)
        .unwrap_err();
    assert_eq!(err, KrrError::UnknownMethod("wlshh".into()));
}

#[test]
fn toml_surfaces_unknown_specs_as_errors() {
    let cfg = Config::parse("[krr]\nmethod = \"wlshh\"\nbudget = 16\n").unwrap();
    assert_eq!(
        KrrConfig::from_config(&cfg),
        Err(KrrError::UnknownMethod("wlshh".into()))
    );
    let cfg = Config::parse("[krr]\nprecond = nystrom(rank=12)\n").unwrap();
    assert_eq!(
        KrrConfig::from_config(&cfg).unwrap().precond,
        PrecondSpec::Nystrom { rank: 12 }
    );
    let cfg = Config::parse("[krr]\nsampling = magic(beans=3)\n").unwrap();
    assert!(matches!(KrrConfig::from_config(&cfg), Err(KrrError::BadParam(_))));
}

#[test]
fn builder_surfaces_sampling_errors_at_fit() {
    let mut ds = wlsh_krr::data::synthetic_by_name("wine", Some(120), 5).unwrap();
    ds.standardize();
    // grammar error from the string form
    let err = KrrModel::builder().sampling("importance").fit(&ds).unwrap_err();
    assert!(matches!(err, KrrError::BadParam(_)), "{err}");
    // range error from validate(): keep exceeds the budget
    let err = KrrModel::builder()
        .budget(16)
        .sampling(SamplingSpec::Leverage { pilot: 4, keep: 48 })
        .fit(&ds)
        .unwrap_err();
    assert!(matches!(err, KrrError::BadParam(_)), "{err}");
    // method error from validate(): importance sampling is WLSH-only
    let err = KrrModel::builder()
        .method(MethodSpec::Rff)
        .sampling(SamplingSpec::Stein)
        .fit(&ds)
        .unwrap_err();
    assert!(matches!(err, KrrError::BadParam(_)), "{err}");
    // and the typed happy path still trains
    let model = KrrModel::builder()
        .budget(16)
        .scale(3.0)
        .sampling(SamplingSpec::Leverage { pilot: 4, keep: 12 })
        .fit(&ds)
        .unwrap();
    assert!(model.predict(&ds.x[..4 * ds.d]).iter().all(|p| p.is_finite()));
}

#[test]
fn toml_config_trains_end_to_end() {
    // the one-code-path claim, exercised: TOML string → typed config →
    // builder-backed training.
    let cfg = Config::parse(
        "[krr]\nmethod = wlsh\nbudget = 16\nbucket = smooth2\ngamma_shape = 7.0\nscale = 3.0\nlambda = 0.5\ncg_max_iters = 40\n",
    )
    .unwrap();
    let krr = KrrConfig::from_config(&cfg).unwrap();
    assert_eq!(krr.method, MethodSpec::Wlsh);
    assert_eq!(krr.bucket, BucketSpec::Smooth(2));
    let mut ds = wlsh_krr::data::synthetic_by_name("wine", Some(200), 2).unwrap();
    ds.standardize();
    let (tr, te) = ds.split(160, 3);
    let model = KrrModel::builder().config(krr).fit(&tr).unwrap();
    let pred = model.predict(&te.x);
    assert_eq!(pred.len(), te.n);
    assert!(pred.iter().all(|p| p.is_finite()));
}
