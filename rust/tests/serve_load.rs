//! CI load-test smoke for the binary's `serve` mode: spawn the server
//! process, fire ≥8 concurrent clients with mixed single/batch requests,
//! check every prediction bit-exactly against an in-process reference
//! model, read stats, and require a clean, timely shutdown (exit 0).
//! Also covers `--checkpoint-out` → `serve --model name=ckpt` routing.
//! The client side drives everything through the typed wire protocol
//! (`coordinator::proto`) — the same `Request`/`Response` types the
//! server parses, so the test doubles as an over-the-wire round-trip
//! check for the typed module.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use wlsh_krr::api::MethodSpec;
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::proto::{Request, Response, StatsReply};
use wlsh_krr::coordinator::{Trainer, TrainedModel};
use wlsh_krr::data::{synthetic_by_name, Dataset};

/// Dataset/config flags shared by every binary invocation below.
const FLAGS: [&str; 8] =
    ["--dataset", "wine", "--n-max", "300", "--budget", "16", "--seed", "7"];

/// The exact model `serve` trains for those flags (mirrors main.rs:
/// synthetic seed = --seed, standardize, 3/4 split at the config seed).
fn reference() -> (Arc<TrainedModel>, Dataset) {
    let mut ds = synthetic_by_name("wine", Some(300), 7).unwrap();
    ds.standardize();
    let n_train = (ds.n * 3) / 4;
    let (tr, te) = ds.split(n_train.min(ds.n - 1), 7);
    let cfg = KrrConfig {
        method: MethodSpec::Wlsh,
        budget: 16,
        scale: 3.0,
        seed: 7,
        ..Default::default()
    };
    (Arc::new(Trainer::new(cfg).train(&tr).unwrap()), te)
}

/// Spawn `wlsh-krr serve` on an ephemeral port and scrape the bound
/// address from its stderr announcement.
fn spawn_serve(extra: &[&str]) -> (Child, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_wlsh-krr"))
        .arg("serve")
        .args(FLAGS)
        .args(["--addr", "127.0.0.1:0"])
        .args(extra)
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn wlsh-krr serve");
    let stderr = child.stderr.take().unwrap();
    let mut reader = BufReader::new(stderr);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read serve stderr");
        assert!(n > 0, "server exited before announcing its address");
        if let Some(rest) = line.strip_prefix("listening on ") {
            break rest.split_whitespace().next().unwrap().to_string();
        }
    };
    // keep draining stderr so the child never blocks on a full pipe
    std::thread::spawn(move || {
        let mut sink = String::new();
        let _ = reader.read_to_string(&mut sink);
    });
    (child, addr)
}

fn wait_with_timeout(child: &mut Child, dur: Duration) -> std::process::ExitStatus {
    let t0 = Instant::now();
    loop {
        if let Some(st) = child.try_wait().expect("try_wait") {
            return st;
        }
        if t0.elapsed() > dur {
            let _ = child.kill();
            panic!("server did not exit within {dur:?} after shutdown");
        }
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn row(x: &[f32], d: usize, qi: usize) -> Vec<f32> {
    x[qi * d..(qi + 1) * d].to_vec()
}

fn send(conn: &mut TcpStream, req: &Request) {
    writeln!(conn, "{}", req.to_line()).unwrap();
}

fn read_resp(reader: &mut BufReader<TcpStream>) -> Response {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Response::parse(line.trim_end()).unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"))
}

fn read_pred(reader: &mut BufReader<TcpStream>) -> f64 {
    match read_resp(reader) {
        Response::Pred(p) => p,
        other => panic!("expected a prediction, got {other:?}"),
    }
}

fn request_stats(addr: &str) -> StatsReply {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    send(&mut conn, &Request::Stats);
    match read_resp(&mut reader) {
        Response::Stats(s) => s,
        other => panic!("expected stats, got {other:?}"),
    }
}

fn shutdown_and_expect_exit_0(mut child: Child, addr: &str) {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    send(&mut conn, &Request::Shutdown);
    match read_resp(&mut reader) {
        Response::Ok { .. } => {}
        other => panic!("expected shutdown ack, got {other:?}"),
    }
    drop(conn);
    let status = wait_with_timeout(&mut child, Duration::from_secs(30));
    assert!(status.success(), "serve exited with {status:?}");
}

#[test]
#[ignore = "heavy: run by CI's dedicated serve load-test step (release, --ignored, serial)"]
fn serve_binary_survives_concurrent_mixed_load_then_exits_cleanly() {
    let (model, te) = reference();
    let d = te.d;
    let nq = te.n;
    let want = model.predict(&te.x);
    let (child, addr) = spawn_serve(&[
        "--workers",
        "2",
        "--queue-depth",
        "256",
        "--linger-us",
        "100",
    ]);
    let clients = 8usize;
    let iters = 24usize; // every 4th request is a batch of 4 rows
    let rows_per_client: usize = (0..iters).map(|r| if r % 4 == 3 { 4 } else { 1 }).sum();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = addr.clone();
            let te_x = &te.x;
            let want = &want;
            scope.spawn(move || {
                let mut conn = TcpStream::connect(&addr).unwrap();
                conn.set_nodelay(true).ok();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                for r in 0..iters {
                    if r % 4 == 3 {
                        let idxs: Vec<usize> =
                            (0..4).map(|k| (c * 7919 + r * 13 + k) % nq).collect();
                        let req = Request::Batch {
                            rows: idxs.iter().map(|&qi| row(te_x, d, qi)).collect(),
                            model: None,
                            var: false,
                        };
                        send(&mut conn, &req);
                        for &qi in &idxs {
                            let got = read_pred(&mut reader);
                            assert!(
                                got == want[qi],
                                "client {c} req {r} row {qi}: {got} vs {}",
                                want[qi]
                            );
                        }
                    } else {
                        let qi = (c * 7919 + r * 13) % nq;
                        let req = Request::Predict {
                            features: row(te_x, d, qi),
                            model: None,
                            var: false,
                        };
                        send(&mut conn, &req);
                        let got = read_pred(&mut reader);
                        assert!(
                            got == want[qi],
                            "client {c} req {r} row {qi}: {got} vs {}",
                            want[qi]
                        );
                    }
                }
            });
        }
    });
    // stats: exact served accounting, sane percentiles, zero rejects
    let stats = request_stats(&addr);
    let total = clients * rows_per_client;
    assert_eq!(stats.served, total);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.workers, 2);
    assert!(
        stats.p50_us > 0.0 && stats.p50_us <= stats.p95_us && stats.p95_us <= stats.p99_us,
        "percentiles {}/{}/{}",
        stats.p50_us,
        stats.p95_us,
        stats.p99_us
    );
    let per_model = stats
        .models
        .iter()
        .find(|(name, _)| name == "default")
        .map(|(_, m)| m.served);
    assert_eq!(per_model, Some(total));
    shutdown_and_expect_exit_0(child, &addr);
}

#[test]
#[ignore = "heavy: run by CI's dedicated serve load-test step (release, --ignored, serial)"]
fn serve_binary_routes_to_named_checkpoints_from_model_flag() {
    let (model, te) = reference();
    let d = te.d;
    let want = model.predict(&te.x[..d * 4]);
    // write the checkpoint with the binary's own train command
    let ckpt = std::env::temp_dir().join("wlsh_serve_load_main.ckpt");
    let out = Command::new(env!("CARGO_BIN_EXE_wlsh-krr"))
        .arg("train")
        .args(FLAGS)
        .args(["--checkpoint-out", ckpt.to_str().unwrap()])
        .output()
        .expect("spawn wlsh-krr train");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let spec = format!("main={}", ckpt.display());
    let (child, addr) = spawn_serve(&["--model", &spec, "--workers", "2"]);
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    for (qi, w) in want.iter().enumerate() {
        // routed explicitly by name
        let req = Request::Predict {
            features: row(&te.x, d, qi),
            model: Some("main".to_string()),
            var: false,
        };
        send(&mut conn, &req);
        let got = read_pred(&mut reader);
        assert!(got == *w, "row {qi}: {got} vs {w}");
    }
    // a single registered model also serves bare requests...
    send(
        &mut conn,
        &Request::Predict { features: row(&te.x, d, 0), model: None, var: false },
    );
    assert!(read_pred(&mut reader) == want[0]);
    // ...and unknown names are a clean error
    send(
        &mut conn,
        &Request::Predict {
            features: row(&te.x, d, 0),
            model: Some("nope".to_string()),
            var: false,
        },
    );
    match read_resp(&mut reader) {
        Response::Error(msg) => assert!(msg.contains("nope"), "{msg}"),
        other => panic!("expected an error, got {other:?}"),
    }
    drop(conn);
    shutdown_and_expect_exit_0(child, &addr);
    std::fs::remove_file(&ckpt).ok();
}
