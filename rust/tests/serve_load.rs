//! CI load-test smoke for the binary's `serve` mode: spawn the server
//! process, fire ≥8 concurrent clients with mixed single/batch requests,
//! check every prediction bit-exactly against an in-process reference
//! model, read stats, and require a clean, timely shutdown (exit 0).
//! Also covers `--checkpoint-out` → `serve --model name=ckpt` routing.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use wlsh_krr::api::MethodSpec;
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::{Trainer, TrainedModel};
use wlsh_krr::data::{synthetic_by_name, Dataset};
use wlsh_krr::util::json::Json;

/// Dataset/config flags shared by every binary invocation below.
const FLAGS: [&str; 8] =
    ["--dataset", "wine", "--n-max", "300", "--budget", "16", "--seed", "7"];

/// The exact model `serve` trains for those flags (mirrors main.rs:
/// synthetic seed = --seed, standardize, 3/4 split at the config seed).
fn reference() -> (Arc<TrainedModel>, Dataset) {
    let mut ds = synthetic_by_name("wine", Some(300), 7).unwrap();
    ds.standardize();
    let n_train = (ds.n * 3) / 4;
    let (tr, te) = ds.split(n_train.min(ds.n - 1), 7);
    let cfg = KrrConfig {
        method: MethodSpec::Wlsh,
        budget: 16,
        scale: 3.0,
        seed: 7,
        ..Default::default()
    };
    (Arc::new(Trainer::new(cfg).train(&tr).unwrap()), te)
}

/// Spawn `wlsh-krr serve` on an ephemeral port and scrape the bound
/// address from its stderr announcement.
fn spawn_serve(extra: &[&str]) -> (Child, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_wlsh-krr"))
        .arg("serve")
        .args(FLAGS)
        .args(["--addr", "127.0.0.1:0"])
        .args(extra)
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn wlsh-krr serve");
    let stderr = child.stderr.take().unwrap();
    let mut reader = BufReader::new(stderr);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read serve stderr");
        assert!(n > 0, "server exited before announcing its address");
        if let Some(rest) = line.strip_prefix("listening on ") {
            break rest.split_whitespace().next().unwrap().to_string();
        }
    };
    // keep draining stderr so the child never blocks on a full pipe
    std::thread::spawn(move || {
        let mut sink = String::new();
        let _ = reader.read_to_string(&mut sink);
    });
    (child, addr)
}

fn wait_with_timeout(child: &mut Child, dur: Duration) -> std::process::ExitStatus {
    let t0 = Instant::now();
    loop {
        if let Some(st) = child.try_wait().expect("try_wait") {
            return st;
        }
        if t0.elapsed() > dur {
            let _ = child.kill();
            panic!("server did not exit within {dur:?} after shutdown");
        }
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn row_json(x: &[f32], d: usize, qi: usize) -> String {
    let feats: Vec<String> = x[qi * d..(qi + 1) * d].iter().map(|v| format!("{v}")).collect();
    format!("[{}]", feats.join(","))
}

fn read_pred(reader: &mut BufReader<TcpStream>) -> f64 {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(&line)
        .unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"))
        .get("pred")
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("no pred in {line:?}"))
}

fn request_stats(addr: &str) -> Json {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    writeln!(conn, "{{\"cmd\": \"stats\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(&line).unwrap_or_else(|e| panic!("bad stats {line:?}: {e}"))
}

fn shutdown_and_expect_exit_0(mut child: Child, addr: &str) {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("ok"), "{line}");
    drop(conn);
    let status = wait_with_timeout(&mut child, Duration::from_secs(30));
    assert!(status.success(), "serve exited with {status:?}");
}

#[test]
#[ignore = "heavy: run by CI's dedicated serve load-test step (release, --ignored, serial)"]
fn serve_binary_survives_concurrent_mixed_load_then_exits_cleanly() {
    let (model, te) = reference();
    let d = te.d;
    let nq = te.n;
    let want = model.predict(&te.x);
    let (child, addr) = spawn_serve(&[
        "--workers",
        "2",
        "--queue-depth",
        "256",
        "--linger-us",
        "100",
    ]);
    let clients = 8usize;
    let iters = 24usize; // every 4th request is a batch of 4 rows
    let rows_per_client: usize = (0..iters).map(|r| if r % 4 == 3 { 4 } else { 1 }).sum();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = addr.clone();
            let te_x = &te.x;
            let want = &want;
            scope.spawn(move || {
                let mut conn = TcpStream::connect(&addr).unwrap();
                conn.set_nodelay(true).ok();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                for r in 0..iters {
                    if r % 4 == 3 {
                        let idxs: Vec<usize> =
                            (0..4).map(|k| (c * 7919 + r * 13 + k) % nq).collect();
                        let rows: Vec<String> =
                            idxs.iter().map(|&qi| row_json(te_x, d, qi)).collect();
                        writeln!(conn, "{{\"batch\": [{}]}}", rows.join(",")).unwrap();
                        for &qi in &idxs {
                            let got = read_pred(&mut reader);
                            assert!(
                                got == want[qi],
                                "client {c} req {r} row {qi}: {got} vs {}",
                                want[qi]
                            );
                        }
                    } else {
                        let qi = (c * 7919 + r * 13) % nq;
                        writeln!(conn, "{{\"features\": {}}}", row_json(te_x, d, qi)).unwrap();
                        let got = read_pred(&mut reader);
                        assert!(
                            got == want[qi],
                            "client {c} req {r} row {qi}: {got} vs {}",
                            want[qi]
                        );
                    }
                }
            });
        }
    });
    // stats: exact served accounting, sane percentiles, zero rejects
    let stats = request_stats(&addr);
    let total = clients * rows_per_client;
    assert_eq!(stats.get("served").and_then(Json::as_usize), Some(total));
    assert_eq!(stats.get("rejected").and_then(Json::as_usize), Some(0));
    assert_eq!(stats.get("workers").and_then(Json::as_usize), Some(2));
    let p50 = stats.get("p50_us").and_then(Json::as_f64).unwrap();
    let p95 = stats.get("p95_us").and_then(Json::as_f64).unwrap();
    let p99 = stats.get("p99_us").and_then(Json::as_f64).unwrap();
    assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "percentiles {p50}/{p95}/{p99}");
    let per_model = stats
        .get("models")
        .and_then(|m| m.get("default"))
        .and_then(|m| m.get("served"))
        .and_then(Json::as_usize);
    assert_eq!(per_model, Some(total));
    shutdown_and_expect_exit_0(child, &addr);
}

#[test]
#[ignore = "heavy: run by CI's dedicated serve load-test step (release, --ignored, serial)"]
fn serve_binary_routes_to_named_checkpoints_from_model_flag() {
    let (model, te) = reference();
    let d = te.d;
    let want = model.predict(&te.x[..d * 4]);
    // write the checkpoint with the binary's own train command
    let ckpt = std::env::temp_dir().join("wlsh_serve_load_main.ckpt");
    let out = Command::new(env!("CARGO_BIN_EXE_wlsh-krr"))
        .arg("train")
        .args(FLAGS)
        .args(["--checkpoint-out", ckpt.to_str().unwrap()])
        .output()
        .expect("spawn wlsh-krr train");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let spec = format!("main={}", ckpt.display());
    let (child, addr) = spawn_serve(&["--model", &spec, "--workers", "2"]);
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    for (qi, w) in want.iter().enumerate() {
        // routed explicitly by name
        writeln!(conn, "{{\"features\": {}, \"model\": \"main\"}}", row_json(&te.x, d, qi))
            .unwrap();
        let got = read_pred(&mut reader);
        assert!(got == *w, "row {qi}: {got} vs {w}");
    }
    // a single registered model also serves bare requests...
    writeln!(conn, "{{\"features\": {}}}", row_json(&te.x, d, 0)).unwrap();
    assert!(read_pred(&mut reader) == want[0]);
    // ...and unknown names are a clean error
    writeln!(conn, "{{\"features\": {}, \"model\": \"nope\"}}", row_json(&te.x, d, 0)).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error") && line.contains("nope"), "{line}");
    drop(conn);
    shutdown_and_expect_exit_0(child, &addr);
    std::fs::remove_file(&ckpt).ok();
}
