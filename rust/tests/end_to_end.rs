//! End-to-end integration: full train → predict → serve pipeline on the
//! synthetic Table-2 datasets, checking that (a) every method learns,
//! (b) the WLSH estimator beats the mean predictor and tracks its exact
//! kernel, and (c) the serving stack returns the same numbers as direct
//! prediction.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use wlsh_krr::api::MethodSpec;
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::{serve, ModelRegistry, PredictRouter, ServerConfig, Trainer};
use wlsh_krr::data::{rmse, synthetic_by_name};
use wlsh_krr::util::json::Json;

#[test]
fn wlsh_tracks_exact_wlsh_kernel_krr() {
    // The m-instance estimator's KRR predictions must approach exact-KRR
    // with the same WLSH kernel as m grows (spectral approx ⇒ solution
    // approx).
    let mut ds = synthetic_by_name("wine", Some(500), 1).unwrap();
    ds.standardize();
    let (tr, te) = ds.split(400, 2);
    let exact_cfg = KrrConfig {
        method: "exact-wlsh".parse().unwrap(),
        bucket: "rect".parse().unwrap(),
        gamma_shape: 2.0,
        scale: 3.0,
        lambda: 1.0,
        cg_max_iters: 300,
        cg_tol: 1e-8,
        ..Default::default()
    };
    let exact = Trainer::new(exact_cfg.clone()).train(&tr).unwrap();
    let exact_pred = exact.predict(&te.x);
    let dist_at = |m: usize| -> f64 {
        let cfg = KrrConfig { method: MethodSpec::Wlsh, budget: m, ..exact_cfg.clone() };
        let model = Trainer::new(cfg).train(&tr).unwrap();
        let pred = model.predict(&te.x);
        rmse(&pred, &exact_pred)
    };
    let d_small = dist_at(16);
    let d_large = dist_at(512);
    assert!(
        d_large < d_small,
        "m=512 distance {d_large} !< m=16 distance {d_small}"
    );
    assert!(d_large < 0.5 * d_small, "rate: {d_small} -> {d_large}");
}

#[test]
fn all_methods_beat_mean_on_synthetic_wine() {
    let mut ds = synthetic_by_name("wine", Some(600), 3).unwrap();
    ds.standardize();
    let (tr, te) = ds.split(480, 4);
    let mean_rmse = rmse(&vec![0.0; te.n], &te.y);
    for (method, budget) in [
        ("wlsh", 200),
        ("rff", 1000),
        ("exact-laplace", 0),
        ("exact-se", 0),
        ("exact-matern", 0),
        ("nystrom", 96),
    ] {
        let cfg = KrrConfig {
            method: method.parse().unwrap(),
            budget,
            scale: 3.0,
            lambda: 0.3,
            cg_max_iters: 150,
            cg_tol: 1e-6,
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&tr).unwrap();
        let err = rmse(&model.predict(&te.x), &te.y);
        assert!(
            err < 0.97 * mean_rmse,
            "{method}: rmse {err} vs mean {mean_rmse}"
        );
    }
}

#[test]
fn router_and_server_agree_with_direct_predict() {
    let mut ds = synthetic_by_name("insurance", Some(400), 5).unwrap();
    ds.standardize();
    let (tr, te) = ds.split(320, 6);
    let cfg = KrrConfig {
        method: MethodSpec::Wlsh,
        budget: 64,
        scale: 5.0,
        lambda: 0.5,
        ..Default::default()
    };
    let model = Arc::new(Trainer::new(cfg).train(&tr).unwrap());
    let direct = model.predict(&te.x);
    // router path
    let router = PredictRouter::new(model.clone(), 4);
    let routed = router.predict(&te.x);
    assert_eq!(routed, direct);
    // server path (first 5 queries)
    let (tx, rx) = std::sync::mpsc::channel();
    let scfg = ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
    let d = te.d;
    let m2 = model.clone();
    let handle =
        std::thread::spawn(move || serve(ModelRegistry::single(m2), scfg, Some(tx)).unwrap());
    let addr = rx.recv().unwrap();
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    for qi in 0..5 {
        let feats: Vec<String> = te.x[qi * d..(qi + 1) * d]
            .iter()
            .map(|v| format!("{v}"))
            .collect();
        writeln!(conn, "{{\"features\": [{}]}}", feats.join(",")).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let got = Json::parse(&line)
            .unwrap()
            .get("pred")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(
            (got - direct[qi]).abs() < 1e-5,
            "query {qi}: {got} vs {}",
            direct[qi]
        );
    }
    writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    handle.join().unwrap();
}

#[test]
fn rank_proxy_grows_sublinearly() {
    // Lemma 30 footnote: the number of non-empty buckets (rank(K̃) proxy)
    // grows slower than n.
    let mk = |n: usize| {
        let mut ds = synthetic_by_name("wine", Some(n), 7).unwrap();
        ds.standardize();
        let cfg = KrrConfig { method: MethodSpec::Wlsh, budget: 8, scale: 3.0, ..Default::default() };
        let trainer = Trainer::new(cfg);
        let op = trainer.build_operator(&ds).unwrap();
        // downcast via name; rebuild directly for the bucket count
        drop(op);
        let sk = wlsh_krr::sketch::WlshSketch::build_mem(
            &ds.x,
            &wlsh_krr::sketch::WlshBuildParams::new(ds.n, ds.d, 8).scale(3.0),
        );
        sk.mean_buckets() / ds.n as f64
    };
    let frac_small = mk(200);
    let frac_large = mk(1600);
    assert!(
        frac_large < frac_small,
        "bucket fraction grew: {frac_small} -> {frac_large}"
    );
}
