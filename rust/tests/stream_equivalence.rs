//! Stream-vs-memory equivalence: the chunked `DataSource` builds must be
//! **bit-identical** to the in-memory builds on the same row stream, for
//! every tested chunk size {1, 7, 64, n} × worker count {1, 2, 8}, for
//! wlsh / rff / nystrom — including end-to-end CG coefficients through
//! `Trainer::train` vs `Trainer::train_source`. Exact f64/f32 equality
//! throughout; no tolerances.

use wlsh_krr::api::MethodSpec;
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::Trainer;
use wlsh_krr::data::{
    head_sample, head_sample_sparse, synthetic_by_name, write_libsvm, DataSource, Dataset,
    LibsvmSource, Standardizer, SyntheticSource,
};
use wlsh_krr::kernels::Kernel;
use wlsh_krr::sketch::{KrrOperator, NystromSketch, RffSketch, WlshBuildParams, WlshSketch};
use wlsh_krr::util::rng::Pcg64;

const CHUNKS: [usize; 3] = [1, 7, 64];
const THREADS: [usize; 3] = [1, 2, 8];

fn standardized_wine(n: usize) -> Dataset {
    let mut ds = synthetic_by_name("wine", Some(n), 11).unwrap();
    ds.standardize();
    ds
}

fn random_beta(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::new(seed, 0);
    (0..n).map(|_| rng.normal()).collect()
}

#[test]
fn wlsh_streamed_build_is_bit_identical_to_in_memory() {
    let ds = standardized_wine(200);
    let m = 16usize;
    let params = WlshBuildParams::new(ds.n, ds.d, m)
        .bucket_str("smooth2")
        .gamma_shape(7.0)
        .scale(3.0)
        .seed(5);
    let want = WlshSketch::build_mem(&ds.x, &params);
    let beta = random_beta(ds.n, 3);
    let queries = &ds.x[..40 * ds.d];
    let want_mv = want.matvec_serial(&beta);
    let want_pred = want.predict(queries, &beta);
    let want_diag = want.diag_values();
    for chunk in CHUNKS.into_iter().chain([ds.n]) {
        for workers in THREADS {
            let got = WlshSketch::build(
                &params.clone().chunk_rows(chunk).workers(workers),
                &ds,
            )
            .unwrap();
            assert_eq!(got.m(), m);
            // instance internals: tables, weights, CSR arrays — all equal
            for (a, b) in want.instances.iter().zip(&got.instances) {
                let tag = format!("chunk={chunk} workers={workers}");
                assert_eq!(a.table.bucket_of, b.table.bucket_of, "{tag} bucket_of");
                assert_eq!(a.table.offsets, b.table.offsets, "{tag} offsets");
                assert_eq!(a.table.members, b.table.members, "{tag} members");
                assert_eq!(a.weights, b.weights, "{tag} weights");
                assert_eq!(a.weights_csr, b.weights_csr, "{tag} weights_csr");
            }
            assert_eq!(got.matvec_serial(&beta), want_mv);
            assert_eq!(got.predict(queries, &beta), want_pred);
            assert_eq!(got.diag_values(), want_diag);
        }
    }
}

#[test]
fn rff_streamed_build_is_bit_identical_to_in_memory() {
    let ds = standardized_wine(200);
    let (dd, scale, seed) = (64usize, 3.0, 7u64);
    let want = RffSketch::build(&ds.x, ds.n, ds.d, dd, scale, seed);
    let beta = random_beta(ds.n, 4);
    let queries = &ds.x[..40 * ds.d];
    let want_mv = want.matvec(&beta);
    let want_pred = want.predict(queries, &beta);
    for chunk in CHUNKS.into_iter().chain([ds.n]) {
        for workers in THREADS {
            let got = RffSketch::build_source(&ds, dd, scale, seed, chunk, workers).unwrap();
            let tag = format!("chunk={chunk} workers={workers}");
            assert_eq!(got.features(), want.features(), "{tag} feature matrix");
            assert_eq!(got.matvec(&beta), want_mv, "{tag} matvec");
            assert_eq!(got.predict(queries, &beta), want_pred, "{tag} predict");
        }
    }
}

#[test]
fn nystrom_streamed_build_is_bit_identical_to_in_memory() {
    let ds = standardized_wine(150);
    let (k, seed) = (24usize, 9u64);
    let want =
        NystromSketch::build(&ds.x, ds.n, ds.d, k, Kernel::squared_exp(3.0), seed).unwrap();
    let beta = random_beta(ds.n, 5);
    let queries = &ds.x[..30 * ds.d];
    let want_mv = want.matvec(&beta);
    let want_pred = want.predict(queries, &beta);
    let want_diag = KrrOperator::diag(&want).unwrap();
    for chunk in CHUNKS.into_iter().chain([ds.n]) {
        for workers in THREADS {
            let got =
                NystromSketch::build_source(&ds, k, Kernel::squared_exp(3.0), seed, chunk, workers)
                    .unwrap();
            let tag = format!("chunk={chunk} workers={workers}");
            assert_eq!(got.matvec(&beta), want_mv, "{tag} matvec");
            assert_eq!(got.predict(queries, &beta), want_pred, "{tag} predict");
            assert_eq!(KrrOperator::diag(&got), Some(want_diag.clone()), "{tag} diag");
        }
    }
}

#[test]
fn end_to_end_cg_coefficients_are_bit_identical_for_every_method() {
    // train() on the materialized dataset vs train_source() on the same
    // rows: identical β, report metadata, and predictions — for all three
    // streaming methods, across chunk sizes and worker counts.
    let ds = standardized_wine(160);
    for method in [MethodSpec::Wlsh, MethodSpec::Rff, MethodSpec::Nystrom] {
        let base = KrrConfig {
            method,
            budget: 24,
            scale: 3.0,
            lambda: 0.4,
            cg_max_iters: 60,
            ..Default::default()
        };
        let want = Trainer::new(base.clone()).train(&ds).unwrap();
        for chunk in CHUNKS.into_iter().chain([ds.n]) {
            for workers in THREADS {
                let cfg = KrrConfig { chunk_rows: chunk, workers, ..base.clone() };
                let got = Trainer::new(cfg).train_source(&ds).unwrap();
                let tag = format!("{method} chunk={chunk} workers={workers}");
                assert_eq!(got.beta, want.beta, "{tag} β");
                assert_eq!(got.report.operator, want.report.operator, "{tag} operator");
                assert_eq!(got.report.cg_iters, want.report.cg_iters, "{tag} iters");
                let q = &ds.x[..20 * ds.d];
                assert_eq!(got.predict(q), want.predict(q), "{tag} predict");
            }
        }
    }
}

#[test]
fn preconditioned_streamed_training_matches_in_memory() {
    // The Nyström preconditioner is itself built from the stream; the
    // whole preconditioned solve must still be bit-identical.
    let ds = standardized_wine(150);
    for precond in ["jacobi", "nystrom(rank=24)"] {
        let base = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 16,
            scale: 3.0,
            lambda: 0.4,
            precond: precond.parse().unwrap(),
            cg_max_iters: 80,
            ..Default::default()
        };
        let want = Trainer::new(base.clone()).train(&ds).unwrap();
        for chunk in [7usize, 64] {
            let cfg = KrrConfig { chunk_rows: chunk, workers: 2, ..base.clone() };
            let got = Trainer::new(cfg).train_source(&ds).unwrap();
            assert_eq!(got.report.precond, want.report.precond, "{precond} chunk={chunk}");
            assert_eq!(got.beta, want.beta, "{precond} chunk={chunk} β");
        }
    }
}

#[test]
fn synthetic_source_streams_identically_to_its_materialization() {
    // An on-the-fly generator (no backing file or matrix) through the
    // streamed trainer vs the same rows materialized through the
    // in-memory trainer.
    let src = SyntheticSource::by_name("wine", 180, 21).unwrap();
    let ds = src.materialize(64).unwrap();
    let cfg = KrrConfig {
        method: MethodSpec::Wlsh,
        budget: 12,
        scale: 4.0,
        lambda: 0.5,
        cg_max_iters: 40,
        chunk_rows: 13,
        workers: 2,
        ..Default::default()
    };
    let want = Trainer::new(cfg.clone()).train(&ds).unwrap();
    let got = Trainer::new(cfg).train_source(&src).unwrap();
    assert_eq!(got.beta, want.beta);
}

/// Zero out ~60% of wine's entries deterministically and serialize the
/// result as a 1-based LIBSVM file (stored nonzeros only). Returns the
/// file path. `write_libsvm` → `LibsvmSource` round-trips values exactly
/// (shortest-round-trip float formatting), so the stream reproduces the
/// sparsified matrix bit for bit.
fn sparse_wine_file(n: usize, name: &str) -> String {
    let mut ds = synthetic_by_name("wine", Some(n), 11).unwrap();
    for i in 0..ds.n {
        for j in 0..ds.d {
            // keep the last feature of row 0 so the file pins d
            if (i * 31 + j * 17) % 10 < 6 && !(i == 0 && j == ds.d - 1) {
                ds.x[i * ds.d + j] = 0.0;
            }
        }
    }
    let path = std::env::temp_dir().join(name).to_string_lossy().into_owned();
    write_libsvm(&ds, &path, false).unwrap();
    path
}

/// Open a sparse LIBSVM stream and materialize its densified equivalent:
/// the dense visitor of a sparse standardized stream applies the same
/// scale-only feature map as the sparse chunks, so a full `head_sample`
/// *is* the densified reference matrix.
fn sparse_stream_and_reference(path: &str) -> (LibsvmSource, Standardizer, Dataset) {
    let src = LibsvmSource::open(path).unwrap();
    assert!(src.is_sparse());
    let standardizer = Standardizer::fit(&src, 64).unwrap();
    let n = src.len_hint().unwrap();
    let dsref = head_sample(&standardizer.source(&src), n, 64).unwrap();
    assert_eq!(dsref.n, n);
    (src, standardizer, dsref)
}

#[test]
fn sparse_streamed_wlsh_build_is_bit_identical_to_densified() {
    let path = sparse_wine_file(160, "wlsh_equiv_sparse_wlsh.libsvm");
    let (src, standardizer, dsref) = sparse_stream_and_reference(&path);
    let view = standardizer.source(&src);
    let n = dsref.n;
    let beta = random_beta(n, 3);
    let queries = &dsref.x[..20 * dsref.d];
    for (bucket_s, shape) in [("rect", 2.0), ("smooth2", 7.0)] {
        let params = WlshBuildParams::new(n, dsref.d, 12)
            .bucket_str(bucket_s)
            .gamma_shape(shape)
            .scale(3.0)
            .seed(5);
        let want = WlshSketch::build_mem(&dsref.x, &params);
        let want_mv = want.matvec_serial(&beta);
        let want_pred = want.predict(queries, &beta);
        let want_diag = want.diag_values();
        for chunk in CHUNKS.into_iter().chain([n]) {
            for workers in THREADS {
                let got = WlshSketch::build(
                    &params.clone().chunk_rows(chunk).workers(workers),
                    &view,
                )
                .unwrap();
                let tag = format!("{bucket_s} chunk={chunk} workers={workers}");
                for (a, b) in want.instances.iter().zip(&got.instances) {
                    assert_eq!(a.table.bucket_of, b.table.bucket_of, "{tag} bucket_of");
                    assert_eq!(a.table.offsets, b.table.offsets, "{tag} offsets");
                    assert_eq!(a.table.members, b.table.members, "{tag} members");
                    assert_eq!(a.weights, b.weights, "{tag} weights");
                    assert_eq!(a.weights_csr, b.weights_csr, "{tag} weights_csr");
                }
                assert_eq!(got.matvec_serial(&beta), want_mv, "{tag} matvec");
                assert_eq!(got.predict(queries, &beta), want_pred, "{tag} predict");
                assert_eq!(got.diag_values(), want_diag, "{tag} diag");
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn sparse_streamed_rff_build_is_bit_identical_to_densified() {
    let path = sparse_wine_file(160, "wlsh_equiv_sparse_rff.libsvm");
    let (src, standardizer, dsref) = sparse_stream_and_reference(&path);
    let view = standardizer.source(&src);
    let n = dsref.n;
    let want = RffSketch::build(&dsref.x, n, dsref.d, 48, 3.0, 7);
    let beta = random_beta(n, 4);
    let queries = &dsref.x[..20 * dsref.d];
    let want_mv = want.matvec(&beta);
    let want_pred = want.predict(queries, &beta);
    for chunk in CHUNKS.into_iter().chain([n]) {
        for workers in THREADS {
            let got = RffSketch::build_source(&view, 48, 3.0, 7, chunk, workers).unwrap();
            let tag = format!("chunk={chunk} workers={workers}");
            assert_eq!(got.features(), want.features(), "{tag} feature matrix");
            assert_eq!(got.matvec(&beta), want_mv, "{tag} matvec");
            assert_eq!(got.predict(queries, &beta), want_pred, "{tag} predict");
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn sparse_streamed_training_matches_densified_training() {
    // End to end: CG coefficients from the sparse CSR stream equal those
    // from training on the densified reference rows, and CSR queries
    // through `predict_sparse_into` equal dense queries bit for bit.
    let path = sparse_wine_file(150, "wlsh_equiv_sparse_train.libsvm");
    let (src, standardizer, dsref) = sparse_stream_and_reference(&path);
    let view = standardizer.source(&src);
    let n = dsref.n;
    let sample = head_sample_sparse(&view, 20, 64).unwrap();
    for method in [MethodSpec::Wlsh, MethodSpec::Rff] {
        let base = KrrConfig {
            method,
            budget: 24,
            scale: 3.0,
            lambda: 0.4,
            cg_max_iters: 60,
            ..Default::default()
        };
        let want = Trainer::new(base.clone()).train(&dsref).unwrap();
        let want_pred = want.predict(&dsref.x[..20 * dsref.d]);
        for chunk in CHUNKS.into_iter().chain([n]) {
            for workers in THREADS {
                let cfg = KrrConfig { chunk_rows: chunk, workers, ..base.clone() };
                let got = Trainer::new(cfg).train_source(&view).unwrap();
                let tag = format!("{method} chunk={chunk} workers={workers}");
                assert_eq!(got.beta, want.beta, "{tag} β");
                let mut sp_pred = vec![0.0f64; sample.n()];
                got.predict_sparse_into(&sample.view(), &mut sp_pred);
                assert_eq!(sp_pred, want_pred, "{tag} sparse predict");
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn operator_memory_excludes_the_training_matrix() {
    // The sketches must not retain O(n·d): on a high-dimensional dataset
    // their reported footprint undercuts the n×d matrix they used to
    // carry (wlsh is O(n) per instance regardless of d; nystrom keeps
    // only C and the landmarks).
    let mut wide = synthetic_by_name("ctslices", Some(200), 1).unwrap(); // d = 384
    wide.standardize();
    let matrix_bytes = wide.n * wide.d * 4;
    let sk = WlshSketch::build_mem(
        &wide.x,
        &WlshBuildParams::new(wide.n, wide.d, 8).gamma_shape(2.0).scale(3.0).seed(2),
    );
    let wlsh_bytes = sk.memory_bytes();
    assert!(
        wlsh_bytes > 0 && wlsh_bytes < matrix_bytes,
        "wlsh footprint {wlsh_bytes} should undercut the {matrix_bytes}-byte matrix"
    );
    let nys = NystromSketch::build(&wide.x, wide.n, wide.d, 10, Kernel::squared_exp(3.0), 3)
        .unwrap();
    let nys_bytes = nys.memory_bytes();
    assert!(
        nys_bytes > 0 && nys_bytes < matrix_bytes,
        "nystrom footprint {nys_bytes} should undercut the {matrix_bytes}-byte matrix"
    );
}
