//! Statistical verification of the paper's theory:
//! * Theorem 11 — the averaged WLSH sketch is an OSE whose ε shrinks like
//!   1/√m and grows with n/λ.
//! * Theorem 12 — the two-cluster lower-bound dataset makes the quadratic
//!   form a rare heavy-atom estimator: P[nonzero] ≈ 2λ/n per instance.
//! * Claim 10 — 0 ⪯ K̃ ⪯ n‖f^{⊗d}‖∞² I.
//! * Claim 22 / Def. 8 — unbiasedness: E[K̃] = K (entrywise, Monte Carlo).

use wlsh_krr::kernels::Kernel;
use wlsh_krr::linalg::sym_eig;
use wlsh_krr::risk::ose_epsilon_dense;
use wlsh_krr::sketch::{ExactKernelOp, KrrOperator, WlshBuildParams, WlshSketch};
use wlsh_krr::solver::materialize;
use wlsh_krr::util::rng::Pcg64;

fn random_x(seed: u64, n: usize, d: usize, spread: f64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 0);
    (0..n * d).map(|_| (rng.normal() * spread) as f32).collect()
}

fn build(x: &[f32], n: usize, d: usize, m: usize, bucket: &str, shape: f64, seed: u64) -> WlshSketch {
    WlshSketch::build_mem(
        x,
        &WlshBuildParams::new(n, d, m).bucket_str(bucket).gamma_shape(shape).seed(seed),
    )
}

#[test]
fn theorem11_eps_rate_in_m() {
    // ε(m) should shrink ≈ 1/√m: quadrupling m should at least halve ε
    // (up to Monte Carlo noise; we average over 3 seeds).
    let (n, d) = (64, 2);
    let x = random_x(1, n, d, 0.8);
    let exact = ExactKernelOp::new(&x, n, d, Kernel::wlsh("rect", 2.0, 1.0));
    let k = materialize(&exact);
    let lambda = 2.0;
    let eps_at = |m: usize| -> f64 {
        (0..3)
            .map(|s| {
                let sk = build(&x, n, d, m, "rect", 2.0, 100 + s);
                ose_epsilon_dense(&k, &sk, lambda).eps
            })
            .sum::<f64>()
            / 3.0
    };
    let e16 = eps_at(16);
    let e64 = eps_at(64);
    let e256 = eps_at(256);
    assert!(e64 < e16, "e64 {e64} !< e16 {e16}");
    assert!(e256 < e64, "e256 {e256} !< e64 {e64}");
    // two quadruplings should shrink eps by ≳ 2.5x (theory: 4x)
    assert!(e256 < e16 / 2.5, "rate too slow: e16={e16} e256={e256}");
}

#[test]
fn theorem11_eps_grows_with_n_over_lambda() {
    // At fixed m, shrinking λ must inflate ε (the n/λ factor in m's bound).
    let (n, d, m) = (64, 2, 64);
    let x = random_x(2, n, d, 0.8);
    let exact = ExactKernelOp::new(&x, n, d, Kernel::wlsh("rect", 2.0, 1.0));
    let k = materialize(&exact);
    let sk = build(&x, n, d, m, "rect", 2.0, 7);
    let eps_hi_lambda = ose_epsilon_dense(&k, &sk, 8.0).eps;
    let eps_lo_lambda = ose_epsilon_dense(&k, &sk, 0.125).eps;
    assert!(
        eps_lo_lambda > eps_hi_lambda,
        "eps(λ=0.125)={eps_lo_lambda} !> eps(λ=8)={eps_hi_lambda}"
    );
}

#[test]
fn theorem12_two_cluster_heavy_atom() {
    // Paper's lower-bound construction: half the points at -λ/n, half at
    // +λ/n (1-d), β = ±1. Each instance's quadratic form is either 0 or
    // n²/2, with P[nonzero] ≤ 2λ/n (and ≈ that, up to constants).
    let n = 64usize;
    let lambda = 4.0f64;
    let d = 1usize;
    let mut x = vec![0.0f32; n];
    let delta = (lambda / n as f64) as f32;
    for i in 0..n / 2 {
        x[i] = -delta;
    }
    for i in n / 2..n {
        x[i] = delta;
    }
    let mut beta = vec![-1.0f64; n];
    for b in beta.iter_mut().skip(n / 2) {
        *b = 1.0;
    }
    let trials = 4000usize;
    let mut nonzero = 0usize;
    for t in 0..trials {
        let sk = build(&x, n, d, 1, "rect", 2.0, 5000 + t as u64);
        let y = sk.matvec(&beta);
        let q: f64 = beta.iter().zip(&y).map(|(a, b)| a * b).sum();
        // quadratic form is 0 (clusters split) or n²/2 (clusters merged,
        // since Σβ over merged bucket is 0... wait: merged bucket has
        // Σβ w = 0 → q = 0; SPLIT buckets give (n/2)² each → n²/2)
        if q > 1.0 {
            nonzero += 1;
            assert!(
                (q - (n * n) as f64 / 2.0).abs() < 1e-6,
                "unexpected atom {q}"
            );
        } else {
            assert!(q.abs() < 1e-9, "unexpected atom {q}");
        }
    }
    let p_hat = nonzero as f64 / trials as f64;
    let p_bound = 2.0 * lambda / n as f64; // = 0.125
    let sigma = (p_bound * (1.0 - p_bound) / trials as f64).sqrt();
    assert!(
        p_hat <= p_bound + 4.0 * sigma,
        "P[nonzero] = {p_hat} exceeds 2λ/n = {p_bound}"
    );
    assert!(
        p_hat > p_bound / 4.0,
        "P[nonzero] = {p_hat} suspiciously far below 2λ/n = {p_bound}"
    );
}

#[test]
fn claim10_psd_and_operator_norm_bound() {
    let (n, d, m) = (48, 3, 4);
    let x = random_x(3, n, d, 1.0);
    for (bucket, shape) in [("rect", 2.0), ("smooth2", 7.0)] {
        let sk = build(&x, n, d, m, bucket, shape, 9);
        let k = materialize(&sk);
        let eig = sym_eig(&k);
        let linf = sk.family.bucket.linf as f64;
        let bound = n as f64 * linf.powi(2 * d as i32);
        assert!(
            eig.values[0] > -1e-8,
            "{bucket}: negative eigenvalue {}",
            eig.values[0]
        );
        assert!(
            *eig.values.last().unwrap() <= bound + 1e-6,
            "{bucket}: ‖K̃‖ {} exceeds n‖f‖∞^2d = {bound}",
            eig.values.last().unwrap()
        );
    }
}

#[test]
fn claim22_unbiasedness_entrywise() {
    // Average K̃ over many sketches; compare to k_{f,p} via quadrature.
    let d = 2usize;
    let x: Vec<f32> = vec![0.0, 0.0, 0.5, -0.2, -0.8, 0.3];
    let n = 3usize;
    let kern = Kernel::wlsh("smooth2", 7.0, 1.0);
    let trials = 1500;
    let mut acc = vec![0.0f64; n * n];
    for t in 0..trials {
        let sk = build(&x, n, d, 4, "smooth2", 7.0, 9000 + t);
        let k = materialize(&sk);
        for i in 0..n {
            for j in 0..n {
                acc[i * n + j] += k[(i, j)];
            }
        }
    }
    for i in 0..n {
        for j in 0..n {
            let got = acc[i * n + j] / trials as f64;
            let want = kern.eval_f32(&x[i * d..(i + 1) * d], &x[j * d..(j + 1) * d]);
            // the smooth-bucket diagonal has heavy weight variance
            // (f⁴ moments); 1500×4 instances put the 3σ band near 0.07
            assert!(
                (got - want).abs() < 0.08,
                "E[K̃[{i}][{j}]] = {got} vs k = {want}"
            );
        }
    }
}

#[test]
fn lemma9_smooth_bucket_gives_differentiable_gp_paths() {
    // §3.2 / Lemma 9: GP paths under the smooth WLSH kernel have bounded
    // derivatives; under the rect/Laplace kernel they do not (OU-like).
    // Finite differences at shrinking h: |Δη|/h stays O(1) for the smooth
    // kernel but grows like h^{-1/2} for the Laplace-family kernel.
    use wlsh_krr::gp::sample_gp_exact;
    let mean_abs_slope = |kern: &Kernel, h: f64, seed: u64| -> f64 {
        let n = 200usize;
        let pts: Vec<f32> = (0..n).map(|i| (i as f64 * h) as f32).collect();
        let mut rng = Pcg64::new(seed, 0);
        let path = sample_gp_exact(kern, &pts, 1, &mut rng).unwrap();
        path.windows(2).map(|w| (w[1] - w[0]).abs() / h).sum::<f64>() / (n - 1) as f64
    };
    let smooth = Kernel::wlsh("smooth2", 7.0, 1.0);
    let rough = Kernel::wlsh("rect", 2.0, 1.0);
    // slope growth when h shrinks 16x: rough ⇒ ×4 (≈ h^{-1/2}), smooth ⇒ ×1
    let growth = |kern: &Kernel| {
        let a: f64 = (0..4).map(|s| mean_abs_slope(kern, 4e-2, 50 + s)).sum::<f64>() / 4.0;
        let b: f64 = (0..4).map(|s| mean_abs_slope(kern, 2.5e-3, 60 + s)).sum::<f64>() / 4.0;
        b / a
    };
    let g_rough = growth(&rough);
    let g_smooth = growth(&smooth);
    assert!(g_rough > 2.0, "Laplace-kernel path growth {g_rough} (want ≈4)");
    assert!(g_smooth < 1.8, "smooth-kernel path growth {g_smooth} (want ≈1)");
    assert!(g_rough > 2.0 * g_smooth, "{g_rough} vs {g_smooth}");
}

#[test]
fn estimator_variance_scales_inversely_with_m() {
    // Averaging m independent instances must shrink the entrywise variance
    // like 1/m — the mechanism behind Theorem 11's m-dependence.
    let d = 1usize;
    let x: Vec<f32> = vec![0.0, 0.05];
    let n = 2usize;
    let kern = Kernel::wlsh("smooth2", 7.0, 1.0);
    let want = kern.eval_f32(&x[0..1], &x[1..2]);
    let var_at = |m: usize, seed0: u64| -> f64 {
        let trials = 600;
        let mut acc2 = 0.0;
        for t in 0..trials {
            let sk = build(&x, n, d, m, "smooth2", 7.0, seed0 + t);
            let y = sk.matvec(&[0.0, 1.0]);
            acc2 += (y[0] - want) * (y[0] - want);
        }
        acc2 / trials as f64
    };
    let v1 = var_at(1, 40_000);
    let v8 = var_at(8, 80_000);
    let ratio = v1 / v8;
    assert!(
        (4.0..16.0).contains(&ratio),
        "var(m=1)/var(m=8) = {ratio}, want ≈ 8"
    );
}
