//! The online-learning subsystem's correctness contract, end to end:
//!
//! * **Append-then-cold-resolve is retraining, bit for bit.** Hashing new
//!   rows into the existing per-instance bucket tables and re-running the
//!   cold CG solve must produce exactly the β a from-scratch
//!   `Trainer::train` on the concatenated data produces — across chunk
//!   sizes, worker-thread counts, and shard counts {1, 2, 4}.
//! * **Warm starts save iterations.** Seeding CG at the previous β
//!   (zero-padded for the new rows) measurably reduces the iteration
//!   count versus the cold solve on the same appended system.
//! * **Hot swaps lose no replies.** A client holding one TCP connection
//!   across `append`-triggered model swaps gets exactly one reply per
//!   request, with predictions always served by a fully-published model.
//!
//! Shard workers run in-thread (`run_worker` on a std thread, addressed
//! through a `remote(...)` topology) — same wire protocol as real
//! `shard-worker` processes, no spawn cost.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Arc, Mutex};

use wlsh_krr::api::{MethodSpec, TopologySpec};
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::{
    run_worker, serve, ModelRegistry, ServerConfig, Trainer, DEFAULT_MODEL,
};
use wlsh_krr::data::{synthetic_by_name, Dataset};
use wlsh_krr::online::{OnlineTrainer, ResolveMode};
use wlsh_krr::util::json::Json;

fn dataset(n: usize) -> Dataset {
    let mut ds = synthetic_by_name("wine", Some(n), 7).expect("dataset");
    ds.standardize();
    ds
}

/// Order-preserving head/tail cut. (`Dataset::split` shuffles, which
/// would break append-vs-retrain bit-identity: the sketch build is
/// row-order-dependent.)
fn cut(ds: &Dataset, at: usize) -> (Dataset, Dataset) {
    let head =
        Dataset::new("head", ds.x[..at * ds.d].to_vec(), ds.y[..at].to_vec(), ds.d);
    let tail =
        Dataset::new("tail", ds.x[at * ds.d..].to_vec(), ds.y[at..].to_vec(), ds.d);
    (head, tail)
}

fn config(chunk_rows: usize, workers: usize) -> KrrConfig {
    KrrConfig {
        method: MethodSpec::Wlsh,
        budget: 24, // 3 FUSE_BLOCKs: a 4-shard plan includes an empty shard
        scale: 3.0,
        lambda: 0.5,
        seed: 7,
        chunk_rows,
        workers,
        cg_max_iters: 400,
        cg_tol: 1e-8,
        ..Default::default()
    }
}

/// Start `n` in-thread shard workers on ephemeral ports; returns their
/// addresses in shard order. The threads serve until process exit.
fn spawn_thread_workers(n: usize) -> Vec<String> {
    let (tx, rx) = mpsc::channel();
    for _ in 0..n {
        let tx = tx.clone();
        std::thread::spawn(move || run_worker("127.0.0.1:0", Some(tx)).unwrap());
    }
    (0..n).map(|_| rx.recv().expect("worker announced its address")).collect()
}

#[test]
fn append_matches_scratch_retrain_across_chunk_sizes_and_threads() {
    let ds = dataset(240);
    let (head, tail) = cut(&ds, 180);
    // 17 leaves a ragged final chunk in both the head build and the append
    for chunk_rows in [17usize, 64] {
        for workers in [1usize, 2] {
            for method in [MethodSpec::Wlsh, MethodSpec::Rff] {
                let mut cfg = config(chunk_rows, workers);
                cfg.method = method;
                let scratch = Trainer::new(cfg.clone()).train(&ds).expect("scratch");
                let mut online =
                    OnlineTrainer::fit(cfg, &head).expect("online fit");
                let (report, model) = online.append(&tail.x, &tail.y).expect("append");
                assert_eq!(report.appended, tail.n);
                assert_eq!(report.n, ds.n);
                assert_eq!(
                    model.beta, scratch.beta,
                    "beta diverged at chunk={chunk_rows} workers={workers} {method:?}"
                );
            }
        }
    }
}

#[test]
fn append_matches_scratch_retrain_across_shard_counts() {
    let ds = dataset(240);
    let (head, tail) = cut(&ds, 180);
    for workers in [1usize, 2] {
        // the sharded solve is itself bit-identical to the local one
        // (tests/shard_equivalence.rs), so the local scratch train is the
        // one reference every shard count must hit
        let scratch = Trainer::new(config(64, workers)).train(&ds).expect("scratch");
        for shards in [1usize, 2, 4] {
            let mut cfg = config(64, workers);
            cfg.topology = TopologySpec::Remote { addrs: spawn_thread_workers(shards) };
            let mut online = OnlineTrainer::fit(cfg, &head).expect("sharded fit");
            let (report, model) = online.append(&tail.x, &tail.y).expect("append");
            assert_eq!(report.appended, tail.n);
            assert_eq!(report.n, ds.n);
            assert_eq!(
                model.beta, scratch.beta,
                "beta diverged at shards={shards} workers={workers}"
            );
            // the swapped-in model serves: predictions match the scratch
            // model exactly (same β, same sketch contents)
            let nq = ds.d * 6;
            assert_eq!(model.predict(&ds.x[..nq]), scratch.predict(&ds.x[..nq]));
        }
    }
}

#[test]
fn successive_appends_stay_bitwise_identical_to_retraining() {
    let ds = dataset(260);
    let cfg = config(64, 1);
    let (head, rest) = cut(&ds, 140);
    let (mid, tail) = cut(&rest, 60);
    let mut online = OnlineTrainer::fit(cfg.clone(), &head).expect("fit");
    online.append(&mid.x, &mid.y).expect("append 1");
    let (_, model) = online.append(&tail.x, &tail.y).expect("append 2");
    let scratch = Trainer::new(cfg).train(&ds).expect("scratch");
    assert_eq!(model.beta, scratch.beta, "two appends != one retrain");
}

#[test]
fn warm_start_reduces_cg_iterations() {
    let ds = dataset(400);
    let (head, tail) = cut(&ds, 384);
    let mut online = OnlineTrainer::fit(config(64, 1), &head).expect("fit");
    // ColdExact runs both solves: the warm one for the report, the cold
    // one for the published (bit-identical) β
    let (report, _) = online.append(&tail.x, &tail.y).expect("append");
    let cold = report.cold_iters.expect("ColdExact measures the cold solve");
    assert!(
        report.warm_iters < cold,
        "warm start saved nothing: warm {} vs cold {}",
        report.warm_iters,
        cold
    );
    // and the warm β itself is solver-tolerance close: publish it
    let (head2, tail2) = cut(&ds, 384);
    let mut warm_online = OnlineTrainer::fit(config(64, 1), &head2).expect("fit");
    warm_online.set_mode(ResolveMode::Warm);
    let (warm_report, warm_model) = warm_online.append(&tail2.x, &tail2.y).expect("append");
    assert!(warm_report.converged);
    assert!(warm_report.cold_iters.is_none(), "Warm mode skips the cold solve");
    let scratch = Trainer::new(config(64, 1)).train(&ds).expect("scratch");
    for (a, b) in warm_model.beta.iter().zip(&scratch.beta) {
        assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn live_connection_survives_hot_swaps_without_losing_replies() {
    let ds = dataset(220);
    let (head, rest) = cut(&ds, 160);
    let cfg = config(64, 1);
    let online = OnlineTrainer::fit(cfg, &head).expect("fit");
    let registry = ModelRegistry::single(online.model());
    registry
        .attach_online(DEFAULT_MODEL, Arc::new(Mutex::new(online)))
        .expect("attach");
    let (tx, rx) = mpsc::channel();
    let scfg = ServerConfig { addr: "127.0.0.1:0".into(), workers: 2, ..Default::default() };
    let server = std::thread::spawn(move || serve(registry, scfg, Some(tx)).unwrap());
    let addr = rx.recv().expect("server announced its address");

    let mut conn = TcpStream::connect(&addr).expect("connect");
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut ask = |req: String| -> Json {
        writeln!(conn, "{req}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "server dropped the connection mid-stream");
        Json::parse(&line).unwrap_or_else(|e| panic!("{req} → {line}: {e}"))
    };
    let row_json = |i: usize| -> String {
        let feats: Vec<String> =
            ds.x[i * ds.d..(i + 1) * ds.d].iter().map(|v| format!("{v}")).collect();
        format!("[{}]", feats.join(","))
    };

    // interleave predicts with appends on ONE connection: every request
    // gets exactly one reply (ask() would wedge or panic otherwise), and
    // every reply is a well-formed prediction
    let d = ds.d;
    let batches = 3usize;
    let per = rest.n / batches;
    let mut sent_rows = 0usize;
    for b in 0..batches {
        for qi in 0..4 {
            let resp = ask(format!("{{\"features\": {}}}", row_json(qi)));
            let p = resp.get("pred").and_then(Json::as_f64).unwrap();
            assert!(p.is_finite(), "batch {b} query {qi}: {p}");
        }
        // uncertainty flows on the same connection
        let resp = ask(format!("{{\"features\": {}, \"var\": true}}", row_json(0)));
        assert!(resp.get("var").and_then(Json::as_f64).unwrap() >= 0.0);
        // append the next slice: the server re-solves and hot-swaps
        let lo = b * per;
        let hi = if b + 1 == batches { rest.n } else { lo + per };
        let rows: Vec<String> = (lo..hi)
            .map(|i| {
                let feats: Vec<String> = rest.x[i * d..(i + 1) * d]
                    .iter()
                    .map(|v| format!("{v}"))
                    .collect();
                format!("[{}]", feats.join(","))
            })
            .collect();
        let targets: Vec<String> =
            rest.y[lo..hi].iter().map(|v| format!("{v}")).collect();
        let resp = ask(format!(
            "{{\"cmd\": \"append\", \"rows\": [{}], \"targets\": [{}]}}",
            rows.join(","),
            targets.join(",")
        ));
        sent_rows += hi - lo;
        assert_eq!(resp.get("appended").and_then(Json::as_usize), Some(hi - lo));
        assert_eq!(resp.get("n").and_then(Json::as_usize), Some(head.n + sent_rows));
        assert_eq!(
            resp.get("generation").and_then(Json::as_usize),
            Some(b + 2),
            "each append must advance the registry generation"
        );
    }
    // after all appends, the served model is bit-identical to a scratch
    // train on the full dataset: β equality is proven in the unit tests,
    // here we check the wire answer agrees with local prediction
    let scratch = Trainer::new(config(64, 1)).train(&ds).expect("scratch");
    let want = scratch.predict(&ds.x[..d]);
    let resp = ask(format!("{{\"features\": {}}}", row_json(0)));
    let got = resp.get("pred").and_then(Json::as_f64).unwrap();
    assert_eq!(got, want[0], "served prediction != scratch retrain prediction");

    let resp = ask("{\"cmd\": \"stats\"}".to_string());
    let generation = resp
        .get("models")
        .and_then(|m| m.get(DEFAULT_MODEL))
        .and_then(|m| m.get("generation"))
        .and_then(Json::as_usize)
        .unwrap();
    assert_eq!(generation, 1 + batches);

    let resp = ask("{\"cmd\": \"shutdown\"}".to_string());
    assert!(resp.get("error").is_none(), "{resp:?}");
    server.join().unwrap();
}
