//! Determinism contract for importance-sampled sketches: leverage and
//! stein builds must be **bit-identical** across worker counts {1, 2, 8},
//! chunk sizes, repeated runs with the same seed, and shard topologies —
//! the same fixed-order discipline the uniform paths already honor
//! (`parallel_determinism.rs`, `shard_equivalence.rs`), extended to the
//! selection step: leverage scores are computed from seeded-fork pilot
//! instances, so the kept (index, weight) set is a pure function of
//! (params, data). Also pins the deprecated-shim contract: the old
//! positional constructors still compile and reproduce the params API
//! bit-for-bit.

use std::sync::mpsc;

use wlsh_krr::api::{MethodSpec, SamplingSpec, TopologySpec};
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::{run_worker, Trainer};
use wlsh_krr::data::{synthetic_by_name, Dataset};
use wlsh_krr::lsh::IdMode;
use wlsh_krr::sketch::{KrrOperator, WlshBuildParams, WlshSketch};
use wlsh_krr::util::rng::Pcg64;

const WORKERS: [usize; 3] = [1, 2, 8];

fn standardized_wine(n: usize) -> Dataset {
    let mut ds = synthetic_by_name("wine", Some(n), 13).unwrap();
    ds.standardize();
    ds
}

fn random_beta(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::new(seed, 0);
    (0..n).map(|_| rng.normal()).collect()
}

fn assert_sketches_bit_equal(got: &WlshSketch, want: &WlshSketch, tag: &str) {
    assert_eq!(got.sampling_info, want.sampling_info, "{tag} sampling_info");
    assert_eq!(got.instances.len(), want.instances.len(), "{tag} m'");
    for (s, (a, b)) in got.instances.iter().zip(&want.instances).enumerate() {
        assert_eq!(a.table.bucket_of, b.table.bucket_of, "{tag} bucket_of[{s}]");
        assert_eq!(a.table.offsets, b.table.offsets, "{tag} offsets[{s}]");
        assert_eq!(a.table.members, b.table.members, "{tag} members[{s}]");
        assert_eq!(a.weights, b.weights, "{tag} weights[{s}]");
        assert_eq!(a.weights_csr, b.weights_csr, "{tag} weights_csr[{s}]");
        assert!(
            a.iweight.to_bits() == b.iweight.to_bits(),
            "{tag} iweight[{s}]: {} vs {}",
            a.iweight,
            b.iweight
        );
    }
}

#[test]
fn sampled_builds_bit_identical_across_workers_chunks_and_reruns() {
    let ds = standardized_wine(200);
    let beta = random_beta(ds.n, 3);
    for (label, sampling, kept) in [
        ("leverage", SamplingSpec::Leverage { pilot: 8, keep: 24 }, 24),
        ("stein", SamplingSpec::Stein, 32),
    ] {
        let params = WlshBuildParams::new(ds.n, ds.d, 32)
            .scale(3.0)
            .seed(7)
            .sampling(sampling)
            .lambda(0.5);
        let want = WlshSketch::build(&params, &ds).unwrap();
        let info = want.sampling_info.as_ref().expect("non-uniform builds record a selection");
        assert_eq!(info.pool_m, 32, "{label} pool");
        assert_eq!(info.kept.len(), kept, "{label} kept");
        assert_eq!(want.instances.len(), kept, "{label} m'");
        let want_mv = want.matvec(&beta);
        for workers in WORKERS {
            for chunk in [7usize, 64, ds.n] {
                let got = WlshSketch::build(
                    &params.clone().chunk_rows(chunk).workers(workers),
                    &ds,
                )
                .unwrap();
                let tag = format!("{label} workers={workers} chunk={chunk}");
                assert_sketches_bit_equal(&got, &want, &tag);
                assert_eq!(got.matvec(&beta), want_mv, "{tag} matvec");
            }
        }
        // a verbatim rerun is a bit-for-bit replay, not merely "close"
        let again = WlshSketch::build(&params, &ds).unwrap();
        assert_sketches_bit_equal(&again, &want, &format!("{label} rerun"));
    }
}

#[test]
fn leverage_training_bit_identical_across_worker_counts() {
    let ds = standardized_wine(200);
    let (tr, te) = ds.split(160, 14);
    let config = |workers: usize| KrrConfig {
        method: MethodSpec::Wlsh,
        budget: 32,
        scale: 3.0,
        lambda: 0.5,
        sampling: SamplingSpec::Leverage { pilot: 8, keep: 24 },
        workers,
        ..Default::default()
    };
    let want = Trainer::new(config(1)).train(&tr).unwrap();
    let want_pred = want.predict(&te.x);
    for workers in WORKERS {
        let got = Trainer::new(config(workers)).train(&tr).unwrap();
        assert_eq!(got.beta, want.beta, "β diverged at workers={workers}");
        assert_eq!(got.predict(&te.x), want_pred, "predictions diverged at workers={workers}");
    }
}

#[test]
fn sharded_leverage_matches_local_bit_for_bit() {
    // the coordinator scores the pool once and ships each shard its
    // (index, weight) slice; with keep = 16 = 2 FUSE_BLOCKs the 4-shard
    // plan includes empty shards, exercising the degenerate wire encoding
    let (tr, te) = standardized_wine(240).split(180, 15);
    let config = || KrrConfig {
        method: MethodSpec::Wlsh,
        budget: 24,
        scale: 3.0,
        lambda: 0.5,
        seed: 11,
        sampling: SamplingSpec::Leverage { pilot: 6, keep: 16 },
        ..Default::default()
    };
    let reference = Trainer::new(config()).train(&tr).expect("local train");
    let want_pred = reference.predict(&te.x);
    for shards in [1usize, 2, 4] {
        let (tx, rx) = mpsc::channel();
        for _ in 0..shards {
            let tx = tx.clone();
            std::thread::spawn(move || run_worker("127.0.0.1:0", Some(tx)).unwrap());
        }
        let addrs = (0..shards).map(|_| rx.recv().expect("worker address")).collect();
        let mut cfg = config();
        cfg.topology = TopologySpec::Remote { addrs };
        let model = Trainer::new(cfg).train(&tr).expect("sharded train");
        assert_eq!(model.beta, reference.beta, "β diverged at shards={shards}");
        assert_eq!(model.predict(&te.x), want_pred, "predictions diverged at shards={shards}");
    }
}

#[test]
#[allow(deprecated)]
fn deprecated_shims_reproduce_the_params_api_bit_for_bit() {
    // the shims exist for out-of-tree callers; in-repo code is migrated.
    // They must stay byte-equivalent to the typed path until removal.
    let ds = standardized_wine(150);
    let beta = random_beta(ds.n, 5);
    let params = WlshBuildParams::new(ds.n, ds.d, 12)
        .bucket_str("smooth2")
        .gamma_shape(7.0)
        .scale(3.0)
        .seed(9);
    let want = WlshSketch::build_mem(&ds.x, &params);
    let via_spec = WlshSketch::build_spec(
        &ds.x,
        ds.n,
        ds.d,
        12,
        &"smooth2".parse().unwrap(),
        7.0,
        3.0,
        9,
    );
    assert_sketches_bit_equal(&via_spec, &want, "build_spec");
    let via_mode = WlshSketch::build_mode(&ds.x, ds.n, ds.d, 12, "smooth2", 7.0, 3.0, 9, IdMode::U64);
    assert_sketches_bit_equal(&via_mode, &want, "build_mode");
    let via_source = WlshSketch::build_source(
        &ds,
        12,
        &"smooth2".parse().unwrap(),
        7.0,
        3.0,
        9,
        IdMode::U64,
        64,
        2,
    )
    .unwrap();
    assert_sketches_bit_equal(&via_source, &want, "build_source");
    assert_eq!(via_source.matvec(&beta), want.matvec(&beta), "shim matvec");
}
