//! Native-vs-XLA backend parity: every AOT artifact family is executed
//! through PJRT and compared against the pure-Rust implementation of the
//! same computation. These tests require `make artifacts` to have run;
//! they are skipped (with a loud message) if the artifacts are missing.

use wlsh_krr::kernels::Kernel;
use wlsh_krr::lsh::{IdMode, LshFamily};
use wlsh_krr::runtime::Runtime;
use wlsh_krr::sketch::{ExactKernelOp, KrrOperator, RffSketch, WlshBuildParams, WlshSketch};
use wlsh_krr::util::rng::Pcg64;

fn runtime() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (artifacts unavailable): {e}");
            None
        }
    }
}

fn random_x(seed: u64, n: usize, d: usize, spread: f64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 0);
    (0..n * d)
        .map(|_| (rng.normal() * spread) as f32)
        .collect()
}

#[test]
fn hash_ids_and_weights_match_native_i32_mode() {
    let Some(rt) = runtime() else { return };
    for (bucket, shape) in [("rect", 2.0), ("smooth2", 7.0)] {
        let (n, d, m) = (500, 11, 7); // deliberately not multiples of chunks
        let x = random_x(1, n, d, 2.0);
        let mut rng = Pcg64::new(5, 0);
        let family = LshFamily::new(d, shape, &bucket.parse().unwrap(), &mut rng);
        let funcs: Vec<_> = (0..m).map(|_| family.sample(&mut rng)).collect();
        let (ids_x, w_x) = rt
            .hash_batch_xla(&x, n, d, &funcs, &family.mix32, bucket)
            .expect("xla hash");
        for (s, f) in funcs.iter().enumerate() {
            let mut ids_n = Vec::new();
            let mut w_n = Vec::new();
            f.hash_batch(&x, &family, IdMode::I32, &mut ids_n, &mut w_n);
            assert_eq!(ids_x[s], ids_n, "{bucket}: ids differ for instance {s}");
            for i in 0..n {
                assert!(
                    (w_x[s][i] - w_n[i]).abs() < 1e-5 * (1.0 + w_n[i].abs()),
                    "{bucket}: weight ({s},{i}): {} vs {}",
                    w_x[s][i],
                    w_n[i]
                );
            }
        }
    }
}

#[test]
fn wlsh_matvec_artifact_matches_native_sketch() {
    let Some(rt) = runtime() else { return };
    let (n, d, m) = (700, 6, 9);
    let x = random_x(2, n, d, 1.0);
    let sk = WlshSketch::build_mem(
        &x,
        &WlshBuildParams::new(n, d, m)
            .bucket_str("smooth2")
            .gamma_shape(7.0)
            .seed(3)
            .id_mode(IdMode::I32),
    );
    let mut rng = Pcg64::new(7, 0);
    let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let want = sk.matvec(&beta);
    // feed the artifact the same dense ids/weights the native table built
    let ids: Vec<Vec<u32>> = sk
        .instances
        .iter()
        .map(|i| i.table.bucket_of.clone())
        .collect();
    let weights: Vec<Vec<f32>> = sk.instances.iter().map(|i| i.weights.clone()).collect();
    let got = rt.wlsh_matvec_xla(&ids, &weights, &beta).expect("xla matvec");
    for i in 0..n {
        assert!(
            (got[i] - want[i]).abs() < 1e-4 * (1.0 + want[i].abs()),
            "row {i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn rff_features_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let (n, d, dd) = (300, 13, 1536);
    let x = random_x(3, n, d, 1.0);
    let native = RffSketch::build(&x, n, d, dd, 1.5, 11);
    let zn = native.featurize(&x);
    // reuse native's omega/b through the artifact path: featurize a fresh
    // sketch is private, so regenerate identically
    let mut rng = Pcg64::new(11, 0);
    let gamma = 1.0 / (1.5f64 * 1.5);
    let sd = (2.0 * gamma).sqrt();
    let omega: Vec<f32> = (0..d * dd).map(|_| (rng.normal() * sd) as f32).collect();
    let b: Vec<f32> = (0..dd)
        .map(|_| rng.uniform_in(0.0, 2.0 * std::f64::consts::PI) as f32)
        .collect();
    let zx = rt
        .rff_features_xla(&x, n, d, &omega, &b, dd)
        .expect("xla rff");
    assert_eq!(zx.len(), zn.len());
    for i in 0..zx.len() {
        assert!(
            (zx[i] - zn[i]).abs() < 2e-5,
            "feature {i}: {} vs {}",
            zx[i],
            zn[i]
        );
    }
}

#[test]
fn exact_matvec_artifacts_match_native() {
    let Some(rt) = runtime() else { return };
    let (n, d) = (900, 11);
    let x = random_x(4, n, d, 1.0);
    let mut rng = Pcg64::new(13, 0);
    let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let scale = 2.5;
    for (kind, kernel) in [
        ("se", Kernel::squared_exp(scale)),
        ("matern52", Kernel::matern52(scale)),
        ("laplace", Kernel::laplace(scale)),
    ] {
        let native = ExactKernelOp::new(&x, n, d, kernel);
        let want = native.matvec(&beta);
        let got = rt
            .exact_matvec_xla(kind, &x, n, &x, n, d, &beta, scale, true)
            .expect("xla exact");
        for i in 0..n {
            assert!(
                (got[i] - want[i]).abs() < 3e-3 * (1.0 + want[i].abs()),
                "{kind} row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn exact_cross_artifacts_match_native_predict() {
    let Some(rt) = runtime() else { return };
    let (n, q, d) = (600, 150, 11);
    let x = random_x(5, n, d, 1.0);
    let xq = random_x(6, q, d, 1.0);
    let mut rng = Pcg64::new(17, 0);
    let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let scale = 2.0;
    for (kind, kernel) in [
        ("se", Kernel::squared_exp(scale)),
        ("matern52", Kernel::matern52(scale)),
        ("laplace", Kernel::laplace(scale)),
    ] {
        let native = ExactKernelOp::new(&x, n, d, kernel);
        let want = native.predict(&xq, &beta);
        let got = rt
            .exact_matvec_xla(kind, &xq, q, &x, n, d, &beta, scale, false)
            .expect("xla cross");
        for i in 0..q {
            assert!(
                (got[i] - want[i]).abs() < 3e-3 * (1.0 + want[i].abs()),
                "{kind} row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn xla_exact_operator_trains_like_native() {
    let Some(rt) = runtime() else { return };
    use wlsh_krr::runtime::XlaExactKernelOp;
    use wlsh_krr::solver::{solve_krr, CgOptions};
    let (n, d) = (400, 8);
    let x = random_x(7, n, d, 1.0);
    let mut rng = Pcg64::new(19, 0);
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let lambda = 0.5;
    let opts = CgOptions { max_iters: 60, tol: 1e-8, verbose: false, x0: None };
    let native = ExactKernelOp::new(&x, n, d, Kernel::squared_exp(2.0));
    let bn = solve_krr(&native, &y, lambda, &opts).beta;
    let xla_op = XlaExactKernelOp::new(&rt, "se", &x, n, d, 2.0);
    let bx = solve_krr(&xla_op, &y, lambda, &opts).beta;
    for i in 0..n {
        assert!(
            (bn[i] - bx[i]).abs() < 1e-3 * (1.0 + bn[i].abs()),
            "beta {i}: {} vs {}",
            bn[i],
            bx[i]
        );
    }
}
