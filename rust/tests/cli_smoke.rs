//! CLI smoke tests: spawn the `wlsh-krr` binary on small synthetic
//! workloads, assert the exit code, and parse the JSON it prints.

use std::process::{Command, Output};

use wlsh_krr::util::json::Json;

fn run(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_wlsh-krr"))
        .args(args)
        .output()
        .expect("spawn wlsh-krr binary")
}

/// Parse the last non-empty stdout line as a JSON object.
fn last_json(out: &Output) -> Json {
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout
        .lines()
        .filter(|l| !l.trim().is_empty())
        .next_back()
        .unwrap_or_else(|| panic!("no stdout; stderr: {}", String::from_utf8_lossy(&out.stderr)));
    Json::parse(line).unwrap_or_else(|e| panic!("bad JSON {line:?}: {e}"))
}

#[test]
fn train_reports_finite_rmse_json() {
    let out = run(&[
        "train",
        "--dataset",
        "wine",
        "--n-max",
        "400",
        "--budget",
        "16",
        "--cg-max-iters",
        "40",
        "--seed",
        "7",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let j = last_json(&out);
    let rmse = j.get("rmse").and_then(Json::as_f64).expect("rmse field");
    assert!(rmse.is_finite() && rmse > 0.0, "rmse {rmse}");
    let op = j.get("operator").and_then(Json::as_str).expect("operator field");
    assert!(op.contains("wlsh"), "operator {op:?}");
    assert!(j.get("cg_iters").and_then(Json::as_usize).unwrap() > 0);
    assert!(j.get("memory_bytes").and_then(Json::as_usize).unwrap() > 0);
}

#[test]
fn train_cg_verbose_emits_iteration_lines_to_stderr() {
    let base = [
        "train", "--dataset", "wine", "--n-max", "300", "--budget", "8", "--cg-max-iters", "20",
        "--seed", "5",
    ];
    // without the flag: no per-iteration chatter
    let quiet = run(&base);
    assert!(quiet.status.success());
    let quiet_err = String::from_utf8_lossy(&quiet.stderr);
    assert!(!quiet_err.contains("cg iter"), "unexpected CG chatter: {quiet_err}");
    // with --cg-verbose=true: one "cg iter" line per iteration on stderr,
    // and stdout JSON stays parseable
    let mut args: Vec<&str> = base.to_vec();
    args.push("--cg-verbose=true");
    let verbose = run(&args);
    assert!(verbose.status.success(), "stderr: {}", String::from_utf8_lossy(&verbose.stderr));
    let verbose_err = String::from_utf8_lossy(&verbose.stderr);
    assert!(verbose_err.contains("cg iter"), "no CG progress lines: {verbose_err}");
    let iters = last_json(&verbose)
        .get("cg_iters")
        .and_then(Json::as_usize)
        .expect("cg_iters field");
    assert_eq!(
        verbose_err.matches("cg iter").count(),
        iters,
        "one progress line per iteration"
    );
}

#[test]
fn train_reports_preconditioner_and_converges_with_each() {
    for precond in ["none", "jacobi", "nystrom"] {
        let out = run(&[
            "train",
            "--dataset",
            "wine",
            "--n-max",
            "300",
            "--budget",
            "16",
            "--precond",
            precond,
            "--precond-rank",
            "24",
            "--seed",
            "7",
        ]);
        assert!(
            out.status.success(),
            "{precond}: stderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let j = last_json(&out);
        assert_eq!(
            j.get("precond").and_then(Json::as_str),
            Some(precond),
            "precond field for {precond}"
        );
        let rmse = j.get("rmse").and_then(Json::as_f64).expect("rmse field");
        assert!(rmse.is_finite() && rmse > 0.0, "{precond}: rmse {rmse}");
    }
}

#[test]
fn train_supports_exact_methods_too() {
    let out = run(&[
        "train",
        "--dataset",
        "wine",
        "--n-max",
        "200",
        "--method",
        "exact-laplace",
        "--seed",
        "3",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let j = last_json(&out);
    assert!(j.get("rmse").and_then(Json::as_f64).unwrap().is_finite());
    assert!(j
        .get("operator")
        .and_then(Json::as_str)
        .unwrap()
        .contains("laplace"));
}

#[test]
fn ose_reports_spectral_sandwich_epsilon() {
    let out = run(&["ose", "--n", "48", "--m", "32", "--lambda", "2.0", "--seed", "3"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let j = last_json(&out);
    let eps = j.get("eps").and_then(Json::as_f64).expect("eps field");
    assert!(eps.is_finite() && eps >= 0.0, "eps {eps}");
    let lo = j.get("lambda_min").and_then(Json::as_f64).unwrap();
    let hi = j.get("lambda_max").and_then(Json::as_f64).unwrap();
    assert!(lo <= hi, "lambda_min {lo} > lambda_max {hi}");
    assert_eq!(j.get("n").and_then(Json::as_usize), Some(48));
    assert_eq!(j.get("m").and_then(Json::as_usize), Some(32));
}

#[test]
fn gp_emits_one_json_record_per_method() {
    let out = run(&["gp", "--cov", "se", "--dim", "2", "--n", "160", "--seed", "5"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    let records: Vec<Json> = stdout
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad JSON {l:?}: {e}")))
        .collect();
    assert_eq!(records.len(), 4, "one record per regression kernel");
    for r in &records {
        assert_eq!(r.get("cov").and_then(Json::as_str), Some("se"));
        let rmse = r.get("rmse").and_then(Json::as_f64).unwrap();
        assert!(rmse.is_finite() && rmse >= 0.0);
    }
    let methods: Vec<&str> = records
        .iter()
        .map(|r| r.get("method").and_then(Json::as_str).unwrap())
        .collect();
    assert!(methods.contains(&"exact-wlsh"), "{methods:?}");
}

#[test]
fn unknown_method_is_a_clean_usage_error() {
    // a typoed spec must exit 2 with one stderr line — not a panic (which
    // would exit 101 and dump a backtrace)
    let out = run(&["train", "--dataset", "wine", "--n-max", "100", "--method", "wlshh"]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown method"), "stderr: {stderr}");
    assert!(stderr.contains("wlshh"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "stderr: {stderr}");
}

#[test]
fn unknown_bucket_and_precond_are_clean_usage_errors() {
    let base = ["train", "--dataset", "wine", "--n-max", "100"];
    for (flag, value, needle) in [
        ("--bucket", "round", "unknown bucket"),
        ("--precond", "ssor", "unknown preconditioner"),
    ] {
        let mut args: Vec<&str> = base.to_vec();
        args.push(flag);
        args.push(value);
        let out = run(&args);
        assert_eq!(out.status.code(), Some(2), "{flag} {value}");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains(needle), "{flag}: stderr: {stderr}");
        assert!(!stderr.contains("panicked"), "{flag}: stderr: {stderr}");
    }
}

#[test]
fn unknown_dataset_is_a_clean_usage_error() {
    let out = run(&["train", "--dataset", "no-such-data"]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown dataset"), "stderr: {stderr}");
}

#[test]
fn gp_unknown_covariance_is_a_clean_usage_error() {
    let out = run(&["gp", "--cov", "cosine", "--n", "40", "--dim", "2"]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown kernel"), "stderr: {stderr}");
}

#[test]
fn bad_numeric_param_is_a_clean_usage_error() {
    let out = run(&["train", "--dataset", "wine", "--n-max", "100", "--scale", "-3.0"]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bad parameter"), "stderr: {stderr}");
}

/// Write a small well-formed CSV (target = last column) for the streamed
/// train tests; returns its path as a String.
fn write_demo_csv(name: &str, rows: usize) -> String {
    let path = std::env::temp_dir().join(name);
    let mut text = String::new();
    for i in 0..rows {
        let a = (i as f64 * 0.37).sin();
        let b = (i as f64 * 0.11).cos();
        let c = 0.01 * i as f64;
        let y = 2.0 * a - b + 0.3 * c;
        text.push_str(&format!("{a:.6},{b:.6},{c:.6},{y:.6}\n"));
    }
    std::fs::write(&path, text).unwrap();
    path.to_string_lossy().into_owned()
}

#[test]
fn streamed_csv_train_reports_throughput_json() {
    let path = write_demo_csv("wlsh_cli_stream.csv", 240);
    let out = run(&[
        "train",
        "--dataset",
        &path,
        "--data-format",
        "csv",
        "--chunk-rows",
        "32",
        "--method",
        "rff",
        "--budget",
        "16",
        "--cg-max-iters",
        "30",
        "--seed",
        "3",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let j = last_json(&out);
    assert_eq!(j.get("data_format").and_then(Json::as_str), Some("csv"));
    assert_eq!(j.get("chunk_rows").and_then(Json::as_usize), Some(32));
    assert_eq!(j.get("n_train").and_then(Json::as_usize), Some(240));
    let rmse = j.get("train_sample_rmse").and_then(Json::as_f64).expect("rmse field");
    assert!(rmse.is_finite() && rmse >= 0.0, "rmse {rmse}");
    let rate = j.get("rows_per_sec").and_then(Json::as_f64).expect("rows_per_sec field");
    assert!(rate > 0.0, "rows_per_sec {rate}");
    // peak_rss_bytes is best-effort (0 off-Linux) but must be present
    assert!(j.get("peak_rss_bytes").and_then(Json::as_usize).is_some());
    assert!(j.get("operator").and_then(Json::as_str).unwrap().contains("rff"));
    std::fs::remove_file(&path).ok();
}

#[test]
fn streamed_libsvm_train_round_trips_through_the_sparse_loader() {
    let path = std::env::temp_dir().join("wlsh_cli_stream.libsvm");
    let mut text = String::new();
    for i in 0..200 {
        let a = (i as f64 * 0.29).sin();
        let y = 1.5 * a;
        // sparse row: feature 2 often omitted (zero)
        if i % 3 == 0 {
            text.push_str(&format!("{y:.6} 1:{a:.6}\n"));
        } else {
            text.push_str(&format!("{y:.6} 1:{a:.6} 2:{:.6}\n", -a));
        }
    }
    std::fs::write(&path, text).unwrap();
    let p = path.to_string_lossy().into_owned();
    let out = run(&[
        "train",
        "--dataset",
        &p,
        "--data-format",
        "libsvm",
        "--chunk-rows",
        "64",
        "--budget",
        "8",
        "--cg-max-iters",
        "20",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let j = last_json(&out);
    assert_eq!(j.get("data_format").and_then(Json::as_str), Some("libsvm"));
    assert_eq!(j.get("n_train").and_then(Json::as_usize), Some(200));
    // LIBSVM streams native CSR chunks by default
    assert_eq!(j.get("sparse"), Some(&Json::Bool(true)));
    assert!(String::from_utf8_lossy(&out.stderr).contains("sparse CSR chunks"));
    assert!(j.get("train_sample_rmse").and_then(Json::as_f64).unwrap().is_finite());
    std::fs::remove_file(&path).ok();
}

/// Write a LIBSVM file whose feature indices are `base..base+2` (3
/// features, every index present on some row) for the index-base tests.
fn write_demo_libsvm(name: &str, rows: usize, base: usize) -> String {
    let path = std::env::temp_dir().join(name);
    let mut text = String::new();
    for i in 0..rows {
        let a = (i as f64 * 0.23).sin();
        let b = (i as f64 * 0.17).cos();
        let y = a - 0.5 * b;
        // drop one feature per row so the file stays genuinely sparse
        match i % 3 {
            0 => text.push_str(&format!("{y:.6} {}:{a:.6} {}:{b:.6}\n", base, base + 1)),
            1 => text.push_str(&format!("{y:.6} {}:{a:.6} {}:{b:.6}\n", base + 1, base + 2)),
            _ => text.push_str(&format!("{y:.6} {}:{a:.6} {}:{b:.6}\n", base, base + 2)),
        }
    }
    std::fs::write(&path, text).unwrap();
    path.to_string_lossy().into_owned()
}

#[test]
fn libsvm_base_flag_pins_the_index_convention() {
    // indices 1..=3, index 0 never appears: the auto heuristic reads this
    // as 1-based (d=3) — pinning --libsvm-base 0 decodes it as d=4
    let p = write_demo_libsvm("wlsh_cli_base.libsvm", 120, 1);
    let base_args: Vec<&str> = vec![
        "train", "--dataset", &p, "--data-format", "libsvm", "--chunk-rows", "32", "--budget",
        "8", "--cg-max-iters", "15",
    ];
    let auto = run(&base_args);
    assert!(auto.status.success(), "stderr: {}", String::from_utf8_lossy(&auto.stderr));
    assert!(
        String::from_utf8_lossy(&auto.stderr).contains("d=3"),
        "stderr: {}",
        String::from_utf8_lossy(&auto.stderr)
    );
    let mut pinned_args = base_args.clone();
    pinned_args.extend(["--libsvm-base", "0"]);
    let pinned = run(&pinned_args);
    assert!(pinned.status.success(), "stderr: {}", String::from_utf8_lossy(&pinned.stderr));
    assert!(
        String::from_utf8_lossy(&pinned.stderr).contains("d=4"),
        "stderr: {}",
        String::from_utf8_lossy(&pinned.stderr)
    );
    std::fs::remove_file(&p).ok();
}

#[test]
fn libsvm_base_conflicts_and_typos_are_clean_errors() {
    // a file that *does* use index 0 cannot be opened as 1-based: runtime
    // data error (exit 1), not a panic
    let p0 = write_demo_libsvm("wlsh_cli_base0.libsvm", 60, 0);
    let out = run(&[
        "train", "--dataset", &p0, "--data-format", "libsvm", "--libsvm-base", "1",
    ]);
    assert_eq!(out.status.code(), Some(1));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("1-based"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "stderr: {stderr}");
    // a typoed base value is usage (exit 2), surfaced before any file I/O
    let out = run(&[
        "train", "--dataset", "/definitely/not/a/file", "--data-format", "libsvm",
        "--libsvm-base", "2",
    ]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("auto|0|1"), "stderr: {stderr}");
    std::fs::remove_file(&p0).ok();
}

#[test]
fn sparse_flag_false_forces_the_dense_pipeline() {
    let p = write_demo_libsvm("wlsh_cli_dense_forced.libsvm", 120, 1);
    let out = run(&[
        "train", "--dataset", &p, "--data-format", "libsvm", "--chunk-rows", "32", "--budget",
        "8", "--cg-max-iters", "15", "--sparse=false",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let j = last_json(&out);
    assert_eq!(j.get("sparse"), Some(&Json::Bool(false)));
    assert!(String::from_utf8_lossy(&out.stderr).contains("dense chunks"));
    assert!(j.get("train_sample_rmse").and_then(Json::as_f64).unwrap().is_finite());
    std::fs::remove_file(&p).ok();
}

#[test]
fn sparse_flag_misuse_is_a_clean_usage_error() {
    // --sparse=true on a dense-only format: usage error, exit 2
    let p = write_demo_csv("wlsh_cli_sparse_csv.csv", 30);
    let out = run(&[
        "train", "--dataset", &p, "--data-format", "csv", "--sparse=true",
    ]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("sparse"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "stderr: {stderr}");
    // a typoed --sparse value is rejected before touching the file
    let out = run(&[
        "train", "--dataset", "/definitely/not/a/file", "--data-format", "libsvm",
        "--sparse=maybe",
    ]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("auto|true|false"), "stderr: {stderr}");
    std::fs::remove_file(&p).ok();
}

#[test]
fn bad_data_format_is_a_clean_usage_error() {
    let path = write_demo_csv("wlsh_cli_badfmt.csv", 20);
    let out = run(&["train", "--dataset", &path, "--data-format", "parquet"]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("csv|libsvm"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "stderr: {stderr}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn zero_chunk_rows_is_a_clean_usage_error() {
    let path = write_demo_csv("wlsh_cli_badchunk.csv", 20);
    let out = run(&[
        "train", "--dataset", &path, "--data-format", "csv", "--chunk-rows", "0",
    ]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("chunk_rows"), "stderr: {stderr}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn malformed_streamed_csv_is_a_runtime_error_not_a_panic() {
    let path = std::env::temp_dir().join("wlsh_cli_ragged.csv");
    std::fs::write(&path, "1,2,3\n4,5\n").unwrap();
    let p = path.to_string_lossy().into_owned();
    let out = run(&["train", "--dataset", &p, "--data-format", "csv"]);
    assert_eq!(out.status.code(), Some(1));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bad dataset"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "stderr: {stderr}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn train_checkpoint_out_writes_a_checkpoint() {
    let path = std::env::temp_dir().join("wlsh_cli_ckpt_out.bin");
    let p = path.to_string_lossy().into_owned();
    let out = run(&[
        "train", "--dataset", "wine", "--n-max", "200", "--budget", "8", "--seed", "3",
        "--checkpoint-out", &p,
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let bytes = std::fs::read(&path).expect("checkpoint written");
    assert_eq!(&bytes[..8], b"WLSHKRR1", "checkpoint magic");
    // the train JSON still lands on stdout
    assert!(last_json(&out).get("rmse").and_then(Json::as_f64).is_some());
    std::fs::remove_file(&path).ok();
}

#[test]
fn serve_malformed_model_flag_is_a_clean_usage_error() {
    // no name=path separator: must exit 2 before loading data or training
    let out = run(&["serve", "--dataset", "wine", "--n-max", "100", "--model", "bogus"]);
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("name=path"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "stderr: {stderr}");
}

#[test]
fn serve_missing_checkpoint_is_a_runtime_error() {
    let out = run(&[
        "serve", "--dataset", "wine", "--n-max", "100", "--model",
        "a=/definitely/not/a/checkpoint",
    ]);
    assert_eq!(out.status.code(), Some(1));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("error"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "stderr: {stderr}");
}

#[test]
fn unknown_subcommand_is_misuse() {
    let out = run(&["definitely-not-a-command"]);
    // usage on stderr, nonzero exit so scripts catch the typo
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("usage"), "stderr: {stderr}");
}

#[test]
fn bare_invocation_prints_usage_and_exits_cleanly() {
    let out = run(&[]);
    assert!(out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("usage"), "stderr: {stderr}");
}
