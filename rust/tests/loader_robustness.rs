//! Loader robustness: CSV/LIBSVM round-trips (write → parse → identical)
//! and malformed inputs — ragged rows, bad floats, empty files,
//! out-of-range target columns, broken index:value pairs — all returning
//! a clean `KrrError::Dataset` (or `Io` for filesystem problems), never a
//! panic, from both the in-memory loader and the streaming sources.

use std::path::PathBuf;

use wlsh_krr::api::KrrError;
use wlsh_krr::data::{
    load_csv, write_csv, write_libsvm, CsvSource, DataSource, Dataset, LibsvmSource,
};

/// Unique temp path per test (tests run concurrently in one process).
fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("wlsh_loader_{name}"))
}

fn sample_dataset() -> Dataset {
    // includes zeros (libsvm sparsity) and negative values; final column
    // nonzero so the libsvm dimensionality survives the round-trip
    let x = vec![
        1.5, 0.0, -2.25, //
        0.0, 3.5, 1.0, //
        -0.5, 0.0, 4.75, //
        2.0, -1.25, 0.5, //
    ];
    let y = vec![0.25, -1.5, 3.0, 0.0];
    Dataset::new("sample", x, y, 3)
}

#[test]
fn csv_roundtrip_write_parse_identical() {
    let ds = sample_dataset();
    let path = tmp("rt.csv");
    let p = path.to_str().unwrap();
    write_csv(&ds, p).unwrap();
    // the in-memory loader and the streaming source agree with the
    // original bit-for-bit (values chosen exactly representable)
    let mem = load_csv(p, -1, "rt").unwrap();
    assert_eq!(mem.x, ds.x);
    assert_eq!(mem.y, ds.y);
    assert_eq!(mem.d, ds.d);
    let streamed = CsvSource::open(p, -1).unwrap().materialize(2).unwrap();
    assert_eq!(streamed.x, ds.x);
    assert_eq!(streamed.y, ds.y);
    std::fs::remove_file(&path).ok();
}

#[test]
fn libsvm_roundtrip_write_parse_identical() {
    let ds = sample_dataset();
    for zero_based in [false, true] {
        let path = tmp(&format!("rt_{zero_based}.libsvm"));
        let p = path.to_str().unwrap();
        write_libsvm(&ds, p, zero_based).unwrap();
        let src = LibsvmSource::open(p).unwrap();
        assert_eq!(src.zero_based(), zero_based, "index base detection");
        let got = src.materialize(3).unwrap();
        assert_eq!(got.x, ds.x, "zero_based={zero_based}");
        assert_eq!(got.y, ds.y, "zero_based={zero_based}");
        assert_eq!(got.d, ds.d);
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn csv_and_libsvm_loaders_agree_on_the_same_data() {
    let ds = sample_dataset();
    let (pc, pl) = (tmp("agree.csv"), tmp("agree.libsvm"));
    write_csv(&ds, pc.to_str().unwrap()).unwrap();
    write_libsvm(&ds, pl.to_str().unwrap(), false).unwrap();
    let a = CsvSource::open(pc.to_str().unwrap(), -1).unwrap().materialize(64).unwrap();
    let b = LibsvmSource::open(pl.to_str().unwrap()).unwrap().materialize(64).unwrap();
    assert_eq!(a.x, b.x);
    assert_eq!(a.y, b.y);
    std::fs::remove_file(&pc).ok();
    std::fs::remove_file(&pl).ok();
}

/// Assert the error is the Dataset variant (clean, no panic path).
fn expect_dataset_err(r: Result<Dataset, KrrError>, what: &str) {
    match r {
        Err(KrrError::Dataset(msg)) => {
            assert!(!msg.is_empty(), "{what}: empty message");
        }
        Err(other) => panic!("{what}: expected KrrError::Dataset, got {other:?}"),
        Ok(_) => panic!("{what}: malformed input parsed successfully"),
    }
}

#[test]
fn malformed_csv_inputs_return_clean_dataset_errors() {
    let cases: [(&str, &str); 4] = [
        ("ragged", "1,2,3\n4,5\n"),
        ("badfloat", "1,2,3\n4,x,6\n"),
        ("empty", ""),
        ("headeronly", "a,b,c\n"),
    ];
    for (name, content) in cases {
        let path = tmp(&format!("bad_{name}.csv"));
        let p = path.to_str().unwrap();
        std::fs::write(&path, content).unwrap();
        // in-memory loader
        match load_csv(p, -1, name) {
            Err(KrrError::Dataset(_)) => {}
            other => panic!("load_csv {name}: {other:?}"),
        }
        // streaming source: the error may surface at open (schema) or at
        // materialize (content), but is always the Dataset variant
        expect_dataset_err(
            CsvSource::open(p, -1).and_then(|s| s.materialize(2)),
            &format!("CsvSource {name}"),
        );
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn csv_target_column_out_of_range_is_a_dataset_error() {
    let path = tmp("target.csv");
    let p = path.to_str().unwrap();
    std::fs::write(&path, "1,2,3\n4,5,6\n").unwrap();
    for col in [3i64, 7, -4] {
        match load_csv(p, col, "t") {
            Err(KrrError::Dataset(msg)) => assert!(msg.contains("target"), "{msg}"),
            other => panic!("load_csv col {col}: {other:?}"),
        }
        expect_dataset_err(
            CsvSource::open(p, col).and_then(|s| s.materialize(2)),
            &format!("CsvSource col {col}"),
        );
    }
    // in-range columns still work, including negative-from-the-end
    assert_eq!(load_csv(p, -3, "t").unwrap().y, vec![1.0, 4.0]);
    std::fs::remove_file(&path).ok();
}

#[test]
fn malformed_libsvm_inputs_return_clean_dataset_errors() {
    let cases: [(&str, &str); 5] = [
        ("badlabel", "x 1:2.0\n"),
        ("nocolon", "1.0 5\n"),
        ("badindex", "1.0 a:2.0\n"),
        ("badvalue", "1.0 1:z\n"),
        ("empty", ""),
    ];
    for (name, content) in cases {
        let path = tmp(&format!("bad_{name}.libsvm"));
        let p = path.to_str().unwrap();
        std::fs::write(&path, content).unwrap();
        expect_dataset_err(
            LibsvmSource::open(p).and_then(|s| s.materialize(2)),
            &format!("LibsvmSource {name}"),
        );
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn libsvm_index_bases_shift_features_as_expected() {
    // hand-written files: same logical row under 1-based and 0-based
    let one = tmp("one.libsvm");
    std::fs::write(&one, "2.5 1:7.0 3:9.0\n-1.0 2:4.0\n").unwrap();
    let src = LibsvmSource::open(one.to_str().unwrap()).unwrap();
    assert!(!src.zero_based());
    let ds = src.materialize(8).unwrap();
    assert_eq!(ds.d, 3);
    assert_eq!(ds.x, vec![7.0, 0.0, 9.0, 0.0, 4.0, 0.0]);
    assert_eq!(ds.y, vec![2.5, -1.0]);
    let zero = tmp("zero.libsvm");
    std::fs::write(&zero, "2.5 0:7.0 2:9.0\n-1.0 1:4.0\n").unwrap();
    let src0 = LibsvmSource::open(zero.to_str().unwrap()).unwrap();
    assert!(src0.zero_based());
    let ds0 = src0.materialize(8).unwrap();
    assert_eq!(ds0.x, ds.x, "0-based file decodes to the same matrix");
    std::fs::remove_file(&one).ok();
    std::fs::remove_file(&zero).ok();
}

#[test]
fn libsvm_explicit_base_overrides_the_ambiguous_heuristic() {
    // A 0-based file whose column 0 is all zeros never *mentions* index 0
    // — the auto heuristic reads it as 1-based (shifted left, d-1), and
    // only an explicit base decodes it correctly.
    let path = tmp("ambig.libsvm");
    let p = path.to_str().unwrap();
    std::fs::write(&path, "1.0 1:5.0 2:6.0\n-1.0 2:7.0\n").unwrap();
    let auto = LibsvmSource::open(p).unwrap();
    assert!(!auto.zero_based(), "heuristic falls back to 1-based");
    assert_eq!(auto.dim(), 2);
    let pinned = LibsvmSource::open_with_base(p, true).unwrap();
    assert!(pinned.zero_based());
    assert_eq!(pinned.dim(), 3);
    let ds = pinned.materialize(4).unwrap();
    assert_eq!(ds.x, vec![0.0, 5.0, 6.0, 0.0, 0.0, 7.0]);
    // pinning 1-based on a file that does use index 0 is a clean error
    let zeroed = tmp("ambig0.libsvm");
    std::fs::write(&zeroed, "1.0 0:5.0\n").unwrap();
    match LibsvmSource::open_with_base(zeroed.to_str().unwrap(), false) {
        Err(KrrError::Dataset(msg)) => assert!(msg.contains("1-based"), "{msg}"),
        Err(other) => panic!("expected Dataset error, got {other:?}"),
        Ok(_) => panic!("expected Dataset error, got a source"),
    }
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&zeroed).ok();
}

#[test]
fn missing_files_are_io_errors_not_dataset_errors() {
    let p = "/definitely/not/here.csv";
    assert!(matches!(load_csv(p, -1, "x"), Err(KrrError::Io(_))));
    assert!(matches!(CsvSource::open(p, -1), Err(KrrError::Io(_))));
    assert!(matches!(LibsvmSource::open(p), Err(KrrError::Io(_))));
}

#[test]
fn loader_errors_name_the_offending_line() {
    let path = tmp("lineno.csv");
    std::fs::write(&path, "1,2,3\n4,5,6\n7,oops,9\n").unwrap();
    let p = path.to_str().unwrap();
    for err in [
        load_csv(p, -1, "l").unwrap_err(),
        CsvSource::open(p, -1).and_then(|s| s.materialize(2)).unwrap_err(),
    ] {
        let msg = err.to_string();
        assert!(msg.contains(":3"), "no line number in {msg:?}");
    }
    std::fs::remove_file(&path).ok();
}
