//! F-OSE — Theorem 11 made measurable: the spectral sandwich error
//! ε̂(m) = max deviation of spec((K+λI)^{-1/2}(K̃+λI)(K+λI)^{-1/2}) from 1,
//! swept over m (expect ε ∝ 1/√m) and over λ at fixed m (expect ε to grow
//! as λ shrinks — the n/λ factor in Theorem 11's bound).

#[path = "common.rs"]
mod common;

use common::{by_scale, f, record, Table};
use wlsh_krr::api::SamplingSpec;
use wlsh_krr::kernels::Kernel;
use wlsh_krr::risk::ose_epsilon_dense;
use wlsh_krr::sketch::{ExactKernelOp, WlshBuildParams, WlshSketch};
use wlsh_krr::solver::materialize;
use wlsh_krr::util::json::JsonWriter;
use wlsh_krr::util::rng::Pcg64;

/// One positional-free sketch build for the sweeps below.
fn build(x: &[f32], n: usize, d: usize, m: usize, bucket: &str, shape: f64, seed: u64) -> WlshSketch {
    WlshSketch::build_mem(
        x,
        &WlshBuildParams::new(n, d, m).bucket_str(bucket).gamma_shape(shape).seed(seed),
    )
}

fn main() {
    let n = by_scale(48, 160, 512);
    let d = 2;
    let trials = by_scale(1, 3, 5);
    let mut rng = Pcg64::new(11, 0);
    let x: Vec<f32> = (0..n * d).map(|_| (rng.normal() * 0.8) as f32).collect();
    let exact = ExactKernelOp::new(&x, n, d, Kernel::wlsh("rect", 2.0, 1.0));
    let k = materialize(&exact);

    println!("=== F-OSE series 1: eps vs m (n={n}, lambda=2) ===\n");
    let t = Table::new(&[("m", 6), ("eps", 10), ("eps*sqrt(m)", 12)]);
    let lambda = 2.0;
    for m in [4usize, 8, 16, 32, 64, 128, 256] {
        let eps: f64 = (0..trials)
            .map(|s| {
                let sk = build(&x, n, d, m, "rect", 2.0, 500 + s as u64);
                ose_epsilon_dense(&k, &sk, lambda).eps
            })
            .sum::<f64>()
            / trials as f64;
        t.row(&[m.to_string(), f(eps, 4), f(eps * (m as f64).sqrt(), 3)]);
        record(
            "ose",
            &JsonWriter::object()
                .field_str("series", "eps_vs_m")
                .field_usize("n", n)
                .field_usize("m", m)
                .field_f64("lambda", lambda)
                .field_f64("eps", eps)
                .finish(),
        );
    }
    println!("\ntheory: eps*sqrt(m) ≈ constant (Theorem 11's 1/eps² rate)\n");

    println!("=== F-OSE series 2: eps vs lambda (n={n}, m=64) ===\n");
    let t2 = Table::new(&[("lambda", 8), ("n/lambda", 9), ("eps", 10)]);
    for lambda in [16.0, 8.0, 4.0, 2.0, 1.0, 0.5, 0.25] {
        let eps: f64 = (0..trials)
            .map(|s| {
                let sk = build(&x, n, d, 64, "rect", 2.0, 900 + s as u64);
                ose_epsilon_dense(&k, &sk, lambda).eps
            })
            .sum::<f64>()
            / trials as f64;
        t2.row(&[f(lambda, 2), f(n as f64 / lambda, 1), f(eps, 4)]);
        record(
            "ose",
            &JsonWriter::object()
                .field_str("series", "eps_vs_lambda")
                .field_usize("n", n)
                .field_usize("m", 64)
                .field_f64("lambda", lambda)
                .field_f64("eps", eps)
                .finish(),
        );
    }
    println!("\ntheory: eps grows as lambda shrinks (m ∝ n/(lambda·eps²))");

    println!("\n=== F-OSE series 3: smooth bucket (smooth2, Gamma(7)) ===\n");
    let exact_s = ExactKernelOp::new(&x, n, d, Kernel::wlsh("smooth2", 7.0, 1.0));
    let ks = materialize(&exact_s);
    let t3 = Table::new(&[("m", 6), ("eps", 10)]);
    for m in [16usize, 64, 256] {
        let eps: f64 = (0..trials)
            .map(|s| {
                let sk = build(&x, n, d, m, "smooth2", 7.0, 1300 + s as u64);
                ose_epsilon_dense(&ks, &sk, 2.0).eps
            })
            .sum::<f64>()
            / trials as f64;
        t3.row(&[m.to_string(), f(eps, 4)]);
        record(
            "ose",
            &JsonWriter::object()
                .field_str("series", "eps_vs_m_smooth")
                .field_usize("m", m)
                .field_f64("eps", eps)
                .finish(),
        );
    }
    println!("\ntheory: same 1/sqrt(m) rate, constant scaled by ||f||_inf^2d (Thm 11)");

    println!("\n=== F-OSE series 4: eps vs kept instances (leverage vs uniform) ===\n");
    // the importance-weighted estimator's spectral error at m' kept
    // instances vs a uniform sketch of the same pool — the OSE view of
    // the accuracy-vs-m claim the ablation bench makes with RMSE
    let t4 = Table::new(&[("pool m", 8), ("sampling", 24), ("kept", 6), ("eps", 10)]);
    for m in [32usize, 64, 128] {
        let pilot = (m / 4).max(4);
        let keep = (m * 3) / 4;
        for (label, sampling, kept) in [
            ("uniform", SamplingSpec::Uniform, m),
            ("leverage", SamplingSpec::Leverage { pilot, keep }, keep),
        ] {
            let eps: f64 = (0..trials)
                .map(|s| {
                    let params = WlshBuildParams::new(n, d, m)
                        .gamma_shape(2.0)
                        .seed(1700 + s as u64)
                        .sampling(sampling)
                        .lambda(lambda);
                    let sk = WlshSketch::build_mem(&x, &params);
                    ose_epsilon_dense(&k, &sk, lambda).eps
                })
                .sum::<f64>()
                / trials as f64;
            t4.row(&[m.to_string(), sampling.to_string(), kept.to_string(), f(eps, 4)]);
            record(
                "ose",
                &JsonWriter::object()
                    .field_str("series", "eps_vs_kept")
                    .field_str("sampling", label)
                    .field_usize("pool_m", m)
                    .field_usize("kept_m", kept)
                    .field_f64("eps", eps)
                    .finish(),
            );
        }
    }
    println!("\nexpect: leverage at 0.75m within a few percent of uniform at m");
}
