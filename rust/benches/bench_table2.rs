//! Table 2 reproduction — "Test set RMSE of different regression methods
//! together with the running times."
//!
//! Datasets (synthetic stand-ins, DESIGN.md §5): wine (6497×11, 4000
//! train), insurance (9822×85, 5822 train), ctslices (53500×384, 35000
//! train), covtype (581012×54, 500000 train). Methods: exact KRR with
//! Laplace/SE/Matérn kernels (budget-capped like the paper's 12-hour
//! limit), RFF at the paper's D, WLSH at the paper's m.
//!
//! Default scale caps the two large datasets (ct→12k rows, covtype→60k)
//! so the whole table runs in minutes on one core; WLSH_BENCH_PAPER=1
//! lifts the caps. Reproduction target: WLSH ≈ exact accuracy on the
//! small datasets at ≥3× less solve time; WLSH beats RFF accuracy on the
//! large, memory-constrained datasets.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use common::{by_scale, f, record, secs, Table};
use wlsh_krr::api::MethodSpec;
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::Trainer;
use wlsh_krr::data::{rmse, synthetic_by_name};
use wlsh_krr::util::json::JsonWriter;

fn main() {
    let exact_budget_secs = by_scale(20.0, 150.0, 43_200.0);
    let caps: [(&str, Option<usize>, usize); 4] = [
        ("wine", None, 4000),
        ("insurance", None, 5822),
        ("ctslices", by_scale(Some(3000), Some(12_000), None), 35_000),
        ("covtype", by_scale(Some(8000), Some(40_000), None), 500_000),
    ];
    println!(
        "=== Table 2: large-scale KRR (exact budget {} per method) ===\n",
        secs(exact_budget_secs)
    );
    let table = Table::new(&[
        ("dataset", 10),
        ("n/d", 12),
        ("method", 16),
        ("rmse", 8),
        ("build", 8),
        ("solve", 8),
        ("iters", 6),
    ]);
    for (name, cap, paper_train) in caps {
        let mut ds = synthetic_by_name(name, cap, 42).expect("dataset");
        ds.standardize();
        let spec_n = spec_of(name).n;
        let n_train = if ds.n == spec_n {
            paper_train
        } else {
            // keep the paper's train fraction under the cap
            (ds.n as f64 * paper_train as f64 / spec_n as f64) as usize
        };
        let (tr, te) = ds.split(n_train.min(ds.n - 100), 1);
        // bandwidths via the median heuristic (L1 for the Laplace family /
        // WLSH-rect, L2 for SE-family / RFF / Matérn)
        let med_l1 = wlsh_krr::data::median_distance(&tr, true, 500, 11);
        let med_l2 = wlsh_krr::data::median_distance(&tr, false, 500, 11);
        let mut preset_wlsh = KrrConfig::paper_preset(name, MethodSpec::Wlsh);
        preset_wlsh.scale = med_l1;
        let mut preset_rff = KrrConfig::paper_preset(name, MethodSpec::Rff);
        preset_rff.scale = med_l2;
        // estimate exact cost: one CG iter is ~n²·d kernel-flops; skip if
        // the budget can't fit ~30 iterations (the paper's ">12 hrs  N/A")
        let flops_per_iter = (tr.n as f64) * (tr.n as f64) * (tr.d as f64) * 4.0;
        let est_exact_secs = 30.0 * flops_per_iter / 2.5e9;
        for method in ["exact-laplace", "exact-se", "exact-matern", "rff", "wlsh"] {
            let is_exact = method.starts_with("exact");
            if is_exact && est_exact_secs > exact_budget_secs {
                table.row(&[
                    name.into(),
                    format!("{}/{}", tr.n, tr.d),
                    method.into(),
                    "N/A".into(),
                    format!(">{}", secs(exact_budget_secs)),
                    "-".into(),
                    "-".into(),
                ]);
                record(
                    "table2",
                    &JsonWriter::object()
                        .field_str("dataset", name)
                        .field_str("method", method)
                        .field_str("status", "over-budget")
                        .finish(),
                );
                continue;
            }
            let base = if method == "rff" { &preset_rff } else { &preset_wlsh };
            let scale = match method {
                "exact-laplace" | "wlsh" => med_l1,
                _ => med_l2, // SE / Matérn / RFF live on L2 distances
            };
            let cfg = KrrConfig {
                method: method.parse().unwrap(),
                scale,
                cg_max_iters: if is_exact { 40 } else { 80 },
                cg_tol: 1e-4,
                ..base.clone()
            };
            let t0 = Instant::now();
            let model = Trainer::new(cfg).train(&tr).expect("train");
            let err = rmse(&model.predict(&te.x), &te.y);
            let total = t0.elapsed().as_secs_f64();
            table.row(&[
                name.into(),
                format!("{}/{}", tr.n, tr.d),
                format!("{}({})", method, base_budget(method, base)),
                f(err, 4),
                secs(model.report.build_secs),
                secs(model.report.solve_secs),
                model.report.cg_iters.to_string(),
            ]);
            record(
                "table2",
                &JsonWriter::object()
                    .field_str("dataset", name)
                    .field_str("method", method)
                    .field_usize("n_train", tr.n)
                    .field_usize("d", tr.d)
                    .field_f64("rmse", err)
                    .field_f64("build_secs", model.report.build_secs)
                    .field_f64("solve_secs", model.report.solve_secs)
                    .field_f64("total_secs", total)
                    .field_usize("cg_iters", model.report.cg_iters)
                    .finish(),
            );
        }
    }
    println!(
        "\npaper: WLSH ≈ exact on wine/insurance at ≥3× speedup; exact N/A on\n\
         ct/covtype; WLSH beats RFF on the two large datasets (3.45 vs 4.10,\n\
         0.720 vs 0.968). Absolute values differ (synthetic data, 1 core)."
    );
}

fn spec_of(name: &str) -> wlsh_krr::data::SyntheticSpec {
    wlsh_krr::data::SPECS
        .iter()
        .find(|s| s.name == name)
        .unwrap()
        .clone()
}

fn base_budget(method: &str, cfg: &KrrConfig) -> String {
    match method {
        "rff" => format!("D={}", cfg.budget),
        "wlsh" => format!("m={}", cfg.budget),
        _ => "exact".into(),
    }
}
