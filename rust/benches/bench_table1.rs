//! Table 1 reproduction — "Test set RMSE for estimating GPs."
//!
//! For each covariance σ ∈ {SE, Laplace, Matérn-5/2} and dimension
//! d ∈ {5, 30}: sample η ~ GP(0, σ) at n uniform points in [0,1]^d, add
//! observation noise, and fit KRR with each regression kernel — Laplace,
//! SE, Matérn-5/2, and the paper's smooth WLSH kernel
//! f = (rect*rect_{1/4}*rect_{1/4})(2x), p = Gamma(7,1).
//!
//! Paper sizes: 4000 points (3000 train / 1000 test). Default here: 1600
//! (1200/400) so the 24-config grid finishes quickly on one core; set
//! WLSH_BENCH_PAPER=1 for the full size. The reproduction target is the
//! *ordering* (matching kernel wins its own covariance row; WLSH tracks
//! Matérn-5/2 closely), not absolute RMSE.

#[path = "common.rs"]
mod common;

use common::{by_scale, f, record, Table};
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::Trainer;
use wlsh_krr::data::{rmse, Dataset};
use wlsh_krr::gp::sample_gp_exact;
use wlsh_krr::kernels::Kernel;
use wlsh_krr::util::json::JsonWriter;
use wlsh_krr::util::rng::Pcg64;

fn main() {
    let n = by_scale(400, 1200, 4000);
    let n_train = n * 3 / 4;
    let noise = 0.05;
    println!("=== Table 1: GP estimation RMSE (n={n}, train={n_train}) ===\n");
    let table = Table::new(&[
        ("cov", 10),
        ("dim", 4),
        ("laplace", 9),
        ("sq-exp", 9),
        ("matern52", 9),
        ("wlsh", 9),
        ("winner", 10),
    ]);
    for (cov_name, cov) in [
        ("se", Kernel::squared_exp(1.0)),
        ("laplace", Kernel::laplace(1.0)),
        ("matern52", Kernel::matern52(1.0)),
    ] {
        for d in [5usize, 30] {
            let mut rng = Pcg64::new(1000 + d as u64, 0);
            let pts: Vec<f32> = (0..n * d).map(|_| rng.uniform() as f32).collect();
            let path = sample_gp_exact(&cov, &pts, d, &mut rng).expect("gp");
            let y: Vec<f64> = path.iter().map(|v| v + noise * rng.normal()).collect();
            let ds = Dataset::new(&format!("gp-{cov_name}-d{d}"), pts, y, d);
            let (tr, te) = ds.split(n_train, 7);
            let mut errs = Vec::new();
            for (method, bucket, shape) in [
                ("exact-laplace", "rect", 2.0),
                ("exact-se", "rect", 2.0),
                ("exact-matern", "rect", 2.0),
                ("exact-wlsh", "smooth2", 7.0),
            ] {
                let cfg = KrrConfig {
                    method: method.parse().unwrap(),
                    bucket: bucket.parse().unwrap(),
                    gamma_shape: shape,
                    scale: 1.0,
                    lambda: 0.02,
                    cg_max_iters: 400,
                    cg_tol: 1e-7,
                    ..Default::default()
                };
                let model = Trainer::new(cfg).train(&tr).expect("train");
                errs.push(rmse(&model.predict(&te.x), &te.y));
            }
            let names = ["laplace", "sq-exp", "matern52", "wlsh"];
            let winner = names[errs
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0];
            table.row(&[
                cov_name.to_string(),
                d.to_string(),
                f(errs[0], 4),
                f(errs[1], 4),
                f(errs[2], 4),
                f(errs[3], 4),
                winner.to_string(),
            ]);
            record(
                "table1",
                &JsonWriter::object()
                    .field_str("cov", cov_name)
                    .field_usize("dim", d)
                    .field_usize("n", n)
                    .field_f64("laplace", errs[0])
                    .field_f64("se", errs[1])
                    .field_f64("matern52", errs[2])
                    .field_f64("wlsh", errs[3])
                    .field_str("winner", winner)
                    .finish(),
            );
        }
    }
    println!(
        "\npaper (n=4000): WLSH beats Matérn on all rows; beats SE at d=5.\n\
         reproduction target: WLSH within a few % of the best smooth kernel\n\
         on smooth covariances, Laplace kernel wins its own row."
    );
}
