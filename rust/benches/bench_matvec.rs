//! F-PERF — the paper's cost model (footnote 2, Lemma 27): one CG
//! iteration costs ≈ n² (exact), ≈ nD (RFF), ≈ nm (WLSH). This bench
//! measures mat-vec wall time over n for each operator — the production
//! fused-CSR WLSH path side by side with the kept pre-fusion baseline
//! (`matvec_unfused`) — plus the WLSH preprocessing (hash+table) rate and
//! the XLA-backend mat-vec.

#[path = "common.rs"]
mod common;

use common::{by_scale, record, secs, Table};
use wlsh_krr::api::BucketSpec;
use wlsh_krr::data::{DensifySource, LibsvmSource};
use wlsh_krr::kernels::Kernel;
use wlsh_krr::lsh::IdMode;
use wlsh_krr::runtime::Runtime;
use wlsh_krr::sketch::{ExactKernelOp, KrrOperator, RffSketch, WlshBuildParams, WlshSketch};
use wlsh_krr::util::json::JsonWriter;
use wlsh_krr::util::rng::Pcg64;
use wlsh_krr::util::timer::bench;

fn main() {
    let d = 54usize; // covtype-like
    let m = 50usize;
    let dd = 1500usize;
    let ns: &[usize] = match common::scale() {
        common::Scale::Fast => &[2048, 8192],
        common::Scale::Default => &[4096, 16384, 65536],
        common::Scale::Paper => &[4096, 16384, 65536, 262144, 524288],
    };
    let exact_cap = by_scale(4096, 16384, 16384);
    println!("=== F-PERF: mat-vec cost vs n (d={d}, m={m}, D={dd}) ===\n");
    let t = Table::new(&[
        ("n", 8),
        ("wlsh", 10),
        ("wlsh ns/pt", 11),
        ("unfused", 10),
        ("fused gain", 10),
        ("rff", 10),
        ("exact", 10),
        ("build(wlsh)", 12),
    ]);
    for &n in ns {
        let mut rng = Pcg64::new(n as u64, 0);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        // WLSH build (preprocessing) timing
        let tb = std::time::Instant::now();
        let wlsh = WlshSketch::build_mem(
            &x,
            &WlshBuildParams::new(n, d, m).gamma_shape(2.0).scale(4.0).seed(1),
        );
        let build_secs = tb.elapsed().as_secs_f64();
        // single-threaded on purpose: this table measures the paper's
        // per-iteration cost model (ops, not cores); the parallel section
        // below measures threading separately. "wlsh" is the production
        // fused-CSR path, "unfused" the pre-fusion per-instance baseline.
        let s_wlsh = bench("wlsh", by_scale(0.05, 0.3, 1.0), || wlsh.matvec_serial(&beta));
        let s_unfused = bench("wlsh-unfused", by_scale(0.05, 0.3, 1.0), || {
            wlsh.matvec_unfused(&beta, 1)
        });
        let rff = RffSketch::build(&x, n, d, dd, 4.0, 2);
        let s_rff = bench("rff", by_scale(0.05, 0.3, 1.0), || rff.matvec(&beta));
        let exact_secs = if n <= exact_cap {
            let ex = ExactKernelOp::new(&x, n, d, Kernel::laplace(4.0));
            Some(bench("exact", by_scale(0.05, 0.3, 1.0), || ex.matvec(&beta)).min_secs)
        } else {
            None
        };
        t.row(&[
            n.to_string(),
            secs(s_wlsh.min_secs),
            format!("{:.1}", s_wlsh.min_secs / (n * m) as f64 * 1e9),
            secs(s_unfused.min_secs),
            format!("{:.2}x", s_unfused.min_secs / s_wlsh.min_secs),
            secs(s_rff.min_secs),
            exact_secs.map(secs).unwrap_or_else(|| "skip".into()),
            secs(build_secs),
        ]);
        record(
            "matvec",
            &JsonWriter::object()
                .field_usize("n", n)
                .field_usize("d", d)
                .field_f64("wlsh_secs", s_wlsh.min_secs)
                .field_f64("wlsh_unfused_secs", s_unfused.min_secs)
                .field_f64("rff_secs", s_rff.min_secs)
                .field_f64("exact_secs", exact_secs.unwrap_or(f64::NAN))
                .field_f64("wlsh_build_secs", build_secs)
                .finish(),
        );
    }
    println!(
        "\ntheory: wlsh scales linearly in n·m, rff in n·D, exact in n²·d —\n\
         the crossover puts WLSH ahead of exact past a few thousand rows\n\
         and ahead of RFF whenever m << D. \"fused gain\" is the CSR fused\n\
         path's speedup over the pre-fusion per-instance baseline (same\n\
         terms, contiguous member/weight walks, one buffer per 8-instance\n\
         block)."
    );

    // SIMD on vs off: the same sketches with the util::simd kernels
    // flipped in-process. Outputs are asserted bit-identical before any
    // timing (the vectorization contract — tests/simd_equivalence.rs pins
    // it across worker counts); the speedups here are what the perf gate's
    // re-baselined numbers bank on.
    {
        use wlsh_krr::util::simd;
        let isa = simd::name(simd::detected());
        let n = *ns.last().unwrap();
        let mut rng = Pcg64::new(n as u64, 3);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let qrows = 256usize.min(n);
        let queries = &x[..qrows * d];
        println!("\n=== SIMD on vs off (detected: {isa}; n={n}, m={m}, D={dd}) ===\n");
        simd::set_enabled(false);
        let wlsh = WlshSketch::build_mem(
            &x,
            &WlshBuildParams::new(n, d, m)
                .bucket_str("smooth2")
                .gamma_shape(7.0)
                .scale(4.0)
                .seed(1),
        );
        let rff = RffSketch::build(&x, n, d, dd, 4.0, 2);
        let off_mv = wlsh.matvec_serial(&beta);
        let off_feat = rff.featurize(queries);
        let budget = by_scale(0.05, 0.3, 1.0);
        let s_mv_off = bench("wlsh-matvec-off", budget, || wlsh.matvec_serial(&beta));
        let s_ld_off = bench("bucket-loads-off", budget, || wlsh.loads_all(&beta, 1));
        let s_rf_off = bench("rff-featurize-off", budget, || rff.featurize(queries));
        simd::set_enabled(true);
        assert_eq!(
            wlsh.matvec_serial(&beta),
            off_mv,
            "SIMD mat-vec is not bit-identical to the scalar reference"
        );
        assert_eq!(
            rff.featurize(queries),
            off_feat,
            "SIMD featurize is not bit-identical to the scalar reference"
        );
        let s_mv_on = bench("wlsh-matvec-on", budget, || wlsh.matvec_serial(&beta));
        let s_ld_on = bench("bucket-loads-on", budget, || wlsh.loads_all(&beta, 1));
        let s_rf_on = bench("rff-featurize-on", budget, || rff.featurize(queries));
        simd::reset();
        let tv = Table::new(&[("kernel", 16), ("off", 10), ("on", 10), ("speedup", 8)]);
        for (name, off, on) in [
            ("wlsh mat-vec", s_mv_off.min_secs, s_mv_on.min_secs),
            ("bucket loads", s_ld_off.min_secs, s_ld_on.min_secs),
            ("rff featurize", s_rf_off.min_secs, s_rf_on.min_secs),
        ] {
            tv.row(&[name.into(), secs(off), secs(on), format!("{:.2}x", off / on)]);
        }
        println!(
            "\n(\"off\" forces the scalar reference kernels, \"on\" the detected\n\
             {isa} path; both produce bit-identical outputs, so the speedup\n\
             carries no accuracy caveat. WLSH_SIMD=auto|on|off overrides\n\
             detection at process level.)"
        );
        record(
            "matvec",
            &JsonWriter::object()
                .field_str("series", "simd")
                .field_str("isa", isa)
                .field_usize("n", n)
                .field_f64("wlsh_matvec_on_secs", s_mv_on.min_secs)
                .field_f64("wlsh_matvec_off_secs", s_mv_off.min_secs)
                .field_f64("bucket_loads_on_secs", s_ld_on.min_secs)
                .field_f64("bucket_loads_off_secs", s_ld_off.min_secs)
                .field_f64("rff_featurize_on_secs", s_rf_on.min_secs)
                .field_f64("rff_featurize_off_secs", s_rf_off.min_secs)
                .finish(),
        );
    }

    // Sparse CSR streaming builds: the operators consume a LIBSVM stream's
    // stored coordinates only, vs the same file forced dense through
    // DensifySource — the per-row hash/featurize win approaches the d/nnz
    // work ratio (file parsing is common to both sides).
    let (sn, sd, snnz) = (by_scale(1000, 4000, 16384), 2000usize, 40usize);
    println!("\n=== sparse CSR streaming build (n={sn}, d={sd}, ~{snnz} nnz/row) ===\n");
    let sparse_path = std::env::temp_dir().join("wlsh_bench_sparse.svm");
    write_sparse_libsvm(&sparse_path, sn, sd, snnz, 11);
    let sp = sparse_path.to_string_lossy().into_owned();
    let src = LibsvmSource::open(&sp).expect("bench libsvm source");
    let dense = DensifySource::new(&src);
    let rect = BucketSpec::Rect;
    let sbudget = by_scale(0.1, 0.3, 0.5);
    let sparse_params = WlshBuildParams::new(sn, sd, m)
        .bucket(rect)
        .gamma_shape(2.0)
        .scale(4.0)
        .seed(1)
        .chunk_rows(2048);
    let s_wlsh_sp = bench("wlsh-sparse", sbudget, || {
        WlshSketch::build(&sparse_params, &src).unwrap()
    });
    let s_wlsh_dn = bench("wlsh-densified", sbudget, || {
        WlshSketch::build(&sparse_params, &dense).unwrap()
    });
    let s_rff_sp = bench("rff-sparse", sbudget, || {
        RffSketch::build_source(&src, 128, 4.0, 2, 2048, 1).unwrap()
    });
    let s_rff_dn = bench("rff-densified", sbudget, || {
        RffSketch::build_source(&dense, 128, 4.0, 2, 2048, 1).unwrap()
    });
    let ts = Table::new(&[("build", 8), ("sparse", 10), ("densified", 10), ("speedup", 8)]);
    ts.row(&[
        "wlsh".into(),
        secs(s_wlsh_sp.min_secs),
        secs(s_wlsh_dn.min_secs),
        format!("{:.1}x", s_wlsh_dn.min_secs / s_wlsh_sp.min_secs),
    ]);
    ts.row(&[
        "rff".into(),
        secs(s_rff_sp.min_secs),
        secs(s_rff_dn.min_secs),
        format!("{:.1}x", s_rff_dn.min_secs / s_rff_sp.min_secs),
    ]);
    record(
        "matvec",
        &JsonWriter::object()
            .field_str("series", "sparse_stream_build")
            .field_usize("n", sn)
            .field_usize("d", sd)
            .field_usize("nnz_row", snnz)
            .field_f64("wlsh_sparse_secs", s_wlsh_sp.min_secs)
            .field_f64("wlsh_densified_secs", s_wlsh_dn.min_secs)
            .field_f64("rff_sparse_secs", s_rff_sp.min_secs)
            .field_f64("rff_densified_secs", s_rff_dn.min_secs)
            .finish(),
    );
    std::fs::remove_file(&sparse_path).ok();

    // Parallel WLSH mat-vec: scoped-thread fan-out over instances, reduced
    // in fixed instance order (bit-identical to serial — asserted here and
    // in tests/parallel_determinism.rs). Expect ≥2× at m ≥ 64 on ≥4 cores.
    let threads = wlsh_krr::util::par::num_threads();
    println!("\n=== parallel WLSH mat-vec (threads={threads}) ===\n");
    let tp = Table::new(&[
        ("n", 8),
        ("m", 6),
        ("serial", 10),
        ("parallel", 10),
        ("speedup", 8),
    ]);
    let par_n = by_scale(8192, 32768, 131072);
    for m_par in [64usize, 128] {
        let mut rng = Pcg64::new(m_par as u64, 5);
        let x: Vec<f32> = (0..par_n * d).map(|_| rng.normal() as f32).collect();
        let beta: Vec<f64> = (0..par_n).map(|_| rng.normal()).collect();
        let wlsh = WlshSketch::build_mem(
            &x,
            &WlshBuildParams::new(par_n, d, m_par).gamma_shape(2.0).scale(4.0).seed(9),
        );
        let serial_out = wlsh.matvec_serial(&beta);
        let par_out = wlsh.matvec_threads(&beta, threads);
        assert_eq!(serial_out, par_out, "parallel mat-vec is not bit-identical to serial");
        let budget = by_scale(0.05, 0.3, 1.0);
        let s_serial = bench("wlsh-serial", budget, || wlsh.matvec_serial(&beta));
        let s_par = bench("wlsh-par", budget, || wlsh.matvec_threads(&beta, threads));
        let speedup = s_serial.min_secs / s_par.min_secs;
        tp.row(&[
            par_n.to_string(),
            m_par.to_string(),
            secs(s_serial.min_secs),
            secs(s_par.min_secs),
            format!("{speedup:.2}x"),
        ]);
        record(
            "matvec",
            &JsonWriter::object()
                .field_str("series", "parallel_vs_serial")
                .field_usize("n", par_n)
                .field_usize("m", m_par)
                .field_usize("threads", threads)
                .field_f64("serial_secs", s_serial.min_secs)
                .field_f64("parallel_secs", s_par.min_secs)
                .field_f64("speedup", speedup)
                .finish(),
        );
    }
    println!(
        "\nreading: per-instance contributions fan out over worker threads and\n\
         reduce in instance order — outputs are bit-identical to serial, so\n\
         the speedup is free of accuracy caveats. Expect ≈ core-count scaling\n\
         once n·m is large enough to amortize thread spawns."
    );

    // Sharded solve: the same end-to-end train (sketch build + CG) with
    // the m instances partitioned across 2 shard workers — run in-thread
    // here, but speaking the full wire protocol over real TCP sockets —
    // vs the single-process train. The gap is the serialization +
    // round-trip tax per CG iteration; CI's baseline tracks it as
    // solve.sharded_secs.
    {
        use std::sync::mpsc;
        use wlsh_krr::api::{MethodSpec, TopologySpec};
        use wlsh_krr::config::KrrConfig;
        use wlsh_krr::coordinator::{run_worker, Trainer};
        use wlsh_krr::data::synthetic_by_name;
        let sn = by_scale(1024, 4096, 16384);
        let shards = 2usize;
        let mut ds = synthetic_by_name("wine", Some(sn), 7).expect("bench dataset");
        ds.standardize();
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 32,
            scale: 3.0,
            lambda: 0.5,
            seed: 7,
            cg_max_iters: 20,
            ..Default::default()
        };
        let (tx, rx) = mpsc::channel();
        for _ in 0..shards {
            let tx = tx.clone();
            std::thread::spawn(move || run_worker("127.0.0.1:0", Some(tx)).unwrap());
        }
        let addrs: Vec<String> = (0..shards).map(|_| rx.recv().expect("worker addr")).collect();
        let mut sharded_cfg = cfg.clone();
        sharded_cfg.topology = TopologySpec::Remote { addrs };
        println!("\n=== sharded solve (n={sn}, m=32, shards={shards}, in-thread workers) ===\n");
        let budget = by_scale(0.3, 1.0, 2.0);
        let s_local = bench("solve-local", budget, || {
            Trainer::new(cfg.clone()).train(&ds).expect("local train")
        });
        let s_sharded = bench("solve-sharded", budget, || {
            Trainer::new(sharded_cfg.clone()).train(&ds).expect("sharded train")
        });
        let tsh = Table::new(&[("topology", 10), ("solve", 10), ("vs local", 9)]);
        tsh.row(&["local".into(), secs(s_local.min_secs), "1.00x".into()]);
        tsh.row(&[
            format!("shards={shards}"),
            secs(s_sharded.min_secs),
            format!("{:.2}x", s_sharded.min_secs / s_local.min_secs),
        ]);
        record(
            "matvec",
            &JsonWriter::object()
                .field_str("series", "sharded_solve")
                .field_usize("n", sn)
                .field_usize("m", 32)
                .field_usize("shards", shards)
                .field_f64("local_solve_secs", s_local.min_secs)
                .field_f64("sharded_secs", s_sharded.min_secs)
                .finish(),
        );
    }

    // Warm vs cold re-solve: fit on the head of the dataset, append a
    // small tail through the online trainer, and compare CG iteration
    // counts — the warm solve starts from the previous β zero-padded for
    // the new rows, the cold solve from zero. ColdExact mode runs both
    // against the identical appended system, so the counts are directly
    // comparable (and deterministic: fixed seeds, fixed reduction order).
    {
        use wlsh_krr::api::MethodSpec;
        use wlsh_krr::config::KrrConfig;
        use wlsh_krr::data::{synthetic_by_name, Dataset};
        use wlsh_krr::online::OnlineTrainer;
        let wn = by_scale(2048, 8192, 32768);
        let tail_rows = wn / 32;
        let mut ds = synthetic_by_name("wine", Some(wn), 7).expect("bench dataset");
        ds.standardize();
        let cut = wn - tail_rows;
        // order-preserving head/tail cut (Dataset::split shuffles)
        let head = Dataset::new(
            "head",
            ds.x[..cut * ds.d].to_vec(),
            ds.y[..cut].to_vec(),
            ds.d,
        );
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 32,
            scale: 3.0,
            lambda: 0.5,
            seed: 7,
            cg_max_iters: 400,
            cg_tol: 1e-8,
            ..Default::default()
        };
        let mut online = OnlineTrainer::fit(cfg, &head).expect("online fit");
        let t0 = std::time::Instant::now();
        let (report, _) = online
            .append(&ds.x[cut * ds.d..], &ds.y[cut..])
            .expect("online append");
        let update_secs = t0.elapsed().as_secs_f64();
        let cold = report.cold_iters.expect("ColdExact measures both solves");
        println!("\n=== warm vs cold re-solve (n={wn}, +{tail_rows} rows, m=32) ===\n");
        let tw = Table::new(&[("resolve", 8), ("cg iters", 9)]);
        tw.row(&["warm".into(), report.warm_iters.to_string()]);
        tw.row(&["cold".into(), cold.to_string()]);
        println!(
            "\n(append + both re-solves took {update_secs:.3}s; the warm start\n\
             saves {} of {cold} iterations because the appended system differs\n\
             from the already-solved one by only {tail_rows} rows, leaving the\n\
             previous β near the new solution)",
            cold.saturating_sub(report.warm_iters)
        );
        record(
            "matvec",
            &JsonWriter::object()
                .field_str("series", "warm_vs_cold_resolve")
                .field_usize("n", wn)
                .field_usize("appended", tail_rows)
                .field_usize("warm_iters", report.warm_iters)
                .field_usize("cold_iters", cold)
                .field_f64("update_secs", update_secs)
                .finish(),
        );
    }

    // XLA-backend mat-vec comparison at a fixed shape (if artifacts exist)
    match Runtime::open_default() {
        Ok(rt) => {
            println!("\n=== XLA backend mat-vec (n=4096) ===\n");
            let n = 4096usize;
            let mut rng = Pcg64::new(99, 0);
            let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            let beta: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let sk = WlshSketch::build_mem(
                &x,
                &WlshBuildParams::new(n, d, m)
                    .bucket(BucketSpec::Rect)
                    .gamma_shape(2.0)
                    .scale(4.0)
                    .seed(3)
                    .id_mode(IdMode::I32),
            );
            let ids: Vec<Vec<u32>> =
                sk.instances.iter().map(|i| i.table.bucket_of.clone()).collect();
            let weights: Vec<Vec<f32>> =
                sk.instances.iter().map(|i| i.weights.clone()).collect();
            let s_native = bench("native", 0.3, || sk.matvec(&beta));
            let s_xla = bench("xla", 0.5, || {
                rt.wlsh_matvec_xla(&ids, &weights, &beta).expect("xla matvec")
            });
            println!("native  {}", s_native.report());
            println!("xla     {}", s_xla.report());
            println!(
                "(xla path pays per-call literal copies of the m×n id/weight\n\
                 arrays; the native path is the production default — DESIGN.md §6)"
            );
            record(
                "matvec",
                &JsonWriter::object()
                    .field_str("series", "xla_vs_native")
                    .field_f64("native_secs", s_native.min_secs)
                    .field_f64("xla_secs", s_xla.min_secs)
                    .finish(),
            );
        }
        Err(e) => println!("\n(xla backend skipped: {e})"),
    }
}

/// Generate an n-row LIBSVM file with ~`nnz` stored values per row over
/// `d` features (1-based indices, ascending random jumps) — no dense
/// n×d matrix is ever materialized.
fn write_sparse_libsvm(path: &std::path::Path, n: usize, d: usize, nnz: usize, seed: u64) {
    use std::io::Write;
    let mut rng = Pcg64::new(seed, 0);
    let file = std::fs::File::create(path).expect("bench libsvm file");
    let mut w = std::io::BufWriter::new(file);
    for i in 0..n {
        let mut line = format!("{:.6}", (i as f64 * 0.37).sin());
        // pin the dimensionality via row 0 (the loader sorts + dedupes)
        if i == 0 {
            line.push_str(&format!(" {d}:0.5"));
        }
        let mut idx = 0usize;
        loop {
            idx += 1 + (rng.uniform() * (2 * d / nnz) as f64) as usize;
            if idx > d {
                break;
            }
            line.push_str(&format!(" {}:{:.4}", idx, rng.uniform() * 2.0 - 1.0));
        }
        writeln!(w, "{line}").expect("bench libsvm write");
    }
}
