//! F-LB — Theorem 12's lower-bound construction, measured: the two-cluster
//! dataset (±λ/n in 1-d, β = ±1) turns each instance's quadratic form into
//! a heavy atom: 0 w.p. 1-p, n²/2 w.p. p ≤ 2λ/n. We measure (a) the atom
//! probability, and (b) the failure probability of the m-average staying
//! within (1±3ε) of its mean, as m grows — requiring m = Ω((n/λ)·log n/ε²)
//! for high confidence.

#[path = "common.rs"]
mod common;

use common::{by_scale, f, record, Table};
use wlsh_krr::sketch::{KrrOperator, WlshBuildParams, WlshSketch};
use wlsh_krr::util::json::JsonWriter;

fn two_cluster(n: usize, lambda: f64) -> (Vec<f32>, Vec<f64>) {
    let delta = (lambda / n as f64) as f32;
    let mut x = vec![-delta; n];
    let mut beta = vec![-1.0f64; n];
    for i in n / 2..n {
        x[i] = delta;
        beta[i] = 1.0;
    }
    (x, beta)
}

fn quad_form(x: &[f32], beta: &[f64], n: usize, m: usize, seed: u64) -> f64 {
    let sk = WlshSketch::build_mem(x, &WlshBuildParams::new(n, 1, m).seed(seed));
    let y = sk.matvec(beta);
    beta.iter().zip(&y).map(|(a, b)| a * b).sum()
}

fn main() {
    let trials = by_scale(300, 1500, 6000);
    println!("=== F-LB series 1: atom probability vs n/lambda ===\n");
    let t = Table::new(&[("n", 6), ("lambda", 8), ("2l/n", 8), ("P[q>0]", 9)]);
    for (n, lambda) in [(32usize, 4.0), (64, 4.0), (128, 4.0), (128, 8.0), (256, 8.0)] {
        let (x, beta) = two_cluster(n, lambda);
        let hits = (0..trials)
            .filter(|&t| quad_form(&x, &beta, n, 1, 10_000 + t as u64) > 1.0)
            .count();
        let p_hat = hits as f64 / trials as f64;
        t.row(&[
            n.to_string(),
            f(lambda, 1),
            f(2.0 * lambda / n as f64, 4),
            f(p_hat, 4),
        ]);
        record(
            "lowerbound",
            &JsonWriter::object()
                .field_str("series", "atom_prob")
                .field_usize("n", n)
                .field_f64("lambda", lambda)
                .field_f64("bound", 2.0 * lambda / n as f64)
                .field_f64("p_hat", p_hat)
                .finish(),
        );
    }
    println!("\ntheory: P[q>0] ≤ 2λ/n (and ≈ Θ(λ/n)) — the rare heavy atom.\n");

    println!("=== F-LB series 2: relative deviation of the m-average ===\n");
    let n = 128usize;
    let lambda = 4.0;
    let (x, beta) = two_cluster(n, lambda);
    // E[q] = βᵀKβ = n²(1-exp(-2λ/n))/2
    let expect = (n * n) as f64 * (1.0 - (-2.0 * lambda / n as f64).exp()) / 2.0;
    let t2 = Table::new(&[("m", 7), ("P[|err|>0.5]", 13), ("P[|err|>0.25]", 13)]);
    let dev_trials = by_scale(60, 200, 600);
    for m in [4usize, 16, 64, 256, 1024] {
        let (mut bad50, mut bad25) = (0usize, 0usize);
        for t in 0..dev_trials {
            let q = quad_form(&x, &beta, n, m, 70_000 + (t * 131) as u64);
            let rel = (q - expect).abs() / expect;
            if rel > 0.5 {
                bad50 += 1;
            }
            if rel > 0.25 {
                bad25 += 1;
            }
        }
        let p50 = bad50 as f64 / dev_trials as f64;
        let p25 = bad25 as f64 / dev_trials as f64;
        t2.row(&[m.to_string(), f(p50, 3), f(p25, 3)]);
        record(
            "lowerbound",
            &JsonWriter::object()
                .field_str("series", "deviation_vs_m")
                .field_usize("n", n)
                .field_f64("lambda", lambda)
                .field_usize("m", m)
                .field_f64("p_dev_50", p50)
                .field_f64("p_dev_25", p25)
                .finish(),
        );
    }
    let m_star = (n as f64 / lambda) * (n as f64).ln();
    println!(
        "\ntheory: failures persist until m = Ω((n/λ)·log n / ε²) ≈ {m_star:.0}·(1/ε²)\n\
         for this (n, λ) — deviation probability must collapse only past that."
    );
}
