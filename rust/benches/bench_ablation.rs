//! F-ABL — design ablations DESIGN.md calls out:
//!   A1  bucket function: rect vs smooth2 on a smooth-GP regression task
//!   A2  m sweep: accuracy/time trade-off on synthetic wine
//!   A3  id mode: u64 vs i32 collapse (build time + accuracy parity)
//!   A4  worker sharding: sketch build time vs worker count
//!   A5  Nyström baseline at matched memory

#[path = "common.rs"]
mod common;

use common::{by_scale, f, record, secs, Table};
use wlsh_krr::api::{MethodSpec, SamplingSpec};
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::Trainer;
use wlsh_krr::data::{rmse, synthetic_by_name, Dataset};
use wlsh_krr::gp::sample_gp_exact;
use wlsh_krr::kernels::Kernel;
use wlsh_krr::lsh::IdMode;
use wlsh_krr::sketch::{WlshBuildParams, WlshSketch};
use wlsh_krr::util::json::JsonWriter;
use wlsh_krr::util::rng::Pcg64;

fn main() {
    a1_bucket_function();
    a2_m_sweep();
    a3_id_mode();
    a4_workers();
    a5_nystrom();
    a6_sampling();
}

fn a1_bucket_function() {
    // Smooth GP target: the smooth WLSH kernel should beat the rect/Laplace
    // one (paper §3.2's motivation for weighted buckets).
    let n = by_scale(300, 900, 3000);
    let d = 5;
    let mut rng = Pcg64::new(21, 0);
    let pts: Vec<f32> = (0..n * d).map(|_| rng.uniform() as f32).collect();
    let path = sample_gp_exact(&Kernel::squared_exp(1.0), &pts, d, &mut rng).unwrap();
    let y: Vec<f64> = path.iter().map(|v| v + 0.05 * rng.normal()).collect();
    let ds = Dataset::new("gp-se-d5", pts, y, d);
    let (tr, te) = ds.split(n * 3 / 4, 22);
    println!("=== A1: bucket function on a smooth GP (exact WLSH kernels) ===\n");
    let t = Table::new(&[("bucket", 10), ("shape", 6), ("rmse", 9)]);
    for (bucket, shape) in [("rect", 2.0), ("smooth2", 7.0), ("smooth3", 7.0)] {
        let cfg = KrrConfig {
            method: "exact-wlsh".parse().unwrap(),
            bucket: bucket.parse().unwrap(),
            gamma_shape: shape,
            scale: 1.0,
            lambda: 0.02,
            cg_max_iters: 300,
            cg_tol: 1e-7,
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&tr).expect("train");
        let err = rmse(&model.predict(&te.x), &te.y);
        t.row(&[bucket.into(), f(shape, 0), f(err, 4)]);
        record(
            "ablation",
            &JsonWriter::object()
                .field_str("series", "bucket_function")
                .field_str("bucket", bucket)
                .field_f64("rmse", err)
                .finish(),
        );
    }
    println!("\nexpect: smooth buckets ≤ rect on smooth targets (paper §3.2)\n");
}

fn a2_m_sweep() {
    let mut ds = synthetic_by_name("wine", Some(by_scale(600, 2000, 6497)), 23).unwrap();
    ds.standardize();
    let (tr, te) = ds.split(ds.n * 3 / 4, 24);
    let med_l1 = wlsh_krr::data::median_distance(&tr, true, 400, 9);
    println!("=== A2: WLSH m sweep (accuracy vs time, wine-synthetic) ===\n");
    let t = Table::new(&[("m", 6), ("rmse", 9), ("build", 9), ("solve", 9)]);
    for m in [10usize, 25, 50, 100, 200, 450] {
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: m,
            scale: med_l1,
            lambda: 0.5,
            ..Default::default()
        };
        let model = Trainer::new(cfg).train(&tr).expect("train");
        let err = rmse(&model.predict(&te.x), &te.y);
        t.row(&[
            m.to_string(),
            f(err, 4),
            secs(model.report.build_secs),
            secs(model.report.solve_secs),
        ]);
        record(
            "ablation",
            &JsonWriter::object()
                .field_str("series", "m_sweep")
                .field_usize("m", m)
                .field_f64("rmse", err)
                .field_f64("build_secs", model.report.build_secs)
                .field_f64("solve_secs", model.report.solve_secs)
                .finish(),
        );
    }
    println!("\nexpect: rmse saturates while cost grows linearly in m\n");
}

fn a3_id_mode() {
    let n = by_scale(2000, 20_000, 100_000);
    let d = 54;
    let mut rng = Pcg64::new(25, 0);
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    println!("=== A3: id collapse u64 vs i32 (n={n}, d={d}, m=50) ===\n");
    let t = Table::new(&[("mode", 6), ("build", 9), ("buckets/inst", 13)]);
    for (label, mode) in [("u64", IdMode::U64), ("i32", IdMode::I32)] {
        let t0 = std::time::Instant::now();
        let sk = WlshSketch::build_mem(
            &x,
            &WlshBuildParams::new(n, d, 50)
                .gamma_shape(2.0)
                .scale(4.0)
                .seed(26)
                .id_mode(mode),
        );
        let b = t0.elapsed().as_secs_f64();
        t.row(&[label.into(), secs(b), f(sk.mean_buckets(), 0)]);
        record(
            "ablation",
            &JsonWriter::object()
                .field_str("series", "id_mode")
                .field_str("mode", label)
                .field_f64("build_secs", b)
                .field_f64("mean_buckets", sk.mean_buckets())
                .finish(),
        );
    }
    println!("\nexpect: identical bucket structure whp; u64 is the native default\n");
}

fn a4_workers() {
    let mut ds = synthetic_by_name("covtype", Some(by_scale(5000, 30_000, 100_000)), 27).unwrap();
    ds.standardize();
    println!("=== A4: sharded sketch build vs workers (1 core ⇒ structural) ===\n");
    let t = Table::new(&[("workers", 8), ("build", 9)]);
    for w in [1usize, 2, 4] {
        let cfg = KrrConfig {
            method: MethodSpec::Wlsh,
            budget: 50,
            scale: 4.0,
            workers: w,
            ..Default::default()
        };
        let trainer = Trainer::new(cfg);
        let t0 = std::time::Instant::now();
        let op = trainer.build_operator(&ds).expect("build");
        let b = t0.elapsed().as_secs_f64();
        t.row(&[w.to_string(), secs(b)]);
        let _ = op.memory_bytes();
        record(
            "ablation",
            &JsonWriter::object()
                .field_str("series", "workers")
                .field_usize("workers", w)
                .field_f64("build_secs", b)
                .finish(),
        );
    }
    println!();
}

fn a5_nystrom() {
    let mut ds = synthetic_by_name("wine", Some(by_scale(600, 2000, 6497)), 29).unwrap();
    ds.standardize();
    let (tr, te) = ds.split(ds.n * 3 / 4, 30);
    let med_l1 = wlsh_krr::data::median_distance(&tr, true, 400, 9);
    let med_l2 = wlsh_krr::data::median_distance(&tr, false, 400, 9);
    println!("=== A5: Nyström baseline vs WLSH at matched budget ===\n");
    let t = Table::new(&[("method", 16), ("rmse", 9), ("total", 9), ("mem(MB)", 9)]);
    for (method, budget) in [("wlsh", 200), ("nystrom", 200), ("rff", 2000)] {
        let cfg = KrrConfig {
            method: method.parse().unwrap(),
            budget,
            scale: if method == "wlsh" { med_l1 } else { med_l2 },
            lambda: 0.5,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let model = Trainer::new(cfg).train(&tr).expect("train");
        let err = rmse(&model.predict(&te.x), &te.y);
        t.row(&[
            format!("{method}({budget})"),
            f(err, 4),
            secs(t0.elapsed().as_secs_f64()),
            f(model.report.memory_bytes as f64 / 1e6, 1),
        ]);
        record(
            "ablation",
            &JsonWriter::object()
                .field_str("series", "nystrom_cmp")
                .field_str("method", method)
                .field_usize("budget", budget)
                .field_f64("rmse", err)
                .finish(),
        );
    }
    println!("\nnote: Nyström is data-dependent (paper §1.1); WLSH is oblivious\n");
}

/// A6 — accuracy vs instance count under importance sampling: at each
/// pool size m, compare uniform (all m at weight 1) against
/// `leverage(pilot=m/4, keep=3m/4)` (25% fewer instances carried through
/// every mat-vec/predict) and `stein` (all m, reweighted). The
/// `rmse_at_m` series is what `scripts/bench_baseline.sh` extracts and
/// the CI accuracy-vs-m smoke gates on: leverage at 0.75m should sit
/// within a few percent of uniform at the full m.
fn a6_sampling() {
    let mut ds = synthetic_by_name("wine", Some(by_scale(600, 2000, 6497)), 31).unwrap();
    ds.standardize();
    let (tr, te) = ds.split(ds.n * 3 / 4, 32);
    let med_l1 = wlsh_krr::data::median_distance(&tr, true, 400, 9);
    println!("=== A6: importance sampling (accuracy vs kept instances, wine-synthetic) ===\n");
    let t = Table::new(&[("pool m", 8), ("sampling", 24), ("kept", 6), ("rmse", 9), ("build", 9)]);
    for m in [32usize, 64, 128] {
        let pilot = (m / 4).max(4);
        let keep = (m * 3) / 4;
        let variants = [
            ("uniform", SamplingSpec::Uniform, m),
            ("leverage", SamplingSpec::Leverage { pilot, keep }, keep),
            ("stein", SamplingSpec::Stein, m),
        ];
        for (label, sampling, kept) in variants {
            let cfg = KrrConfig {
                method: MethodSpec::Wlsh,
                budget: m,
                scale: med_l1,
                lambda: 0.5,
                sampling,
                ..Default::default()
            };
            let model = Trainer::new(cfg).train(&tr).expect("train");
            let err = rmse(&model.predict(&te.x), &te.y);
            t.row(&[
                m.to_string(),
                sampling.to_string(),
                kept.to_string(),
                f(err, 4),
                secs(model.report.build_secs),
            ]);
            record(
                "ablation",
                &JsonWriter::object()
                    .field_str("series", "rmse_at_m")
                    .field_str("sampling", label)
                    .field_usize("pool_m", m)
                    .field_usize("kept_m", kept)
                    .field_f64("rmse", err)
                    .field_f64("build_secs", model.report.build_secs)
                    .finish(),
            );
        }
    }
    println!(
        "\nexpect: leverage at 0.75m tracks uniform at m (fewer instances\n\
         per mat-vec at matched accuracy); stein reweights without dropping\n"
    );
}
