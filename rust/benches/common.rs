//! Shared bench harness (criterion is unavailable offline): table printing,
//! JSONL result capture, and the scale knobs.
//!
//! Every bench honors two environment variables:
//!   WLSH_BENCH_PAPER=1  — run at the paper's full sizes (slow on 1 core)
//!   WLSH_BENCH_FAST=1   — minimum sizes (CI smoke)

#![allow(dead_code)]

use std::io::Write;

/// Scale regime for a bench run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Fast,
    Default,
    Paper,
}

pub fn scale() -> Scale {
    if std::env::var("WLSH_BENCH_PAPER").map(|v| v == "1").unwrap_or(false) {
        Scale::Paper
    } else if std::env::var("WLSH_BENCH_FAST").map(|v| v == "1").unwrap_or(false) {
        Scale::Fast
    } else {
        Scale::Default
    }
}

/// Pick by scale: (fast, default, paper).
pub fn by_scale<T: Copy>(fast: T, default: T, paper: T) -> T {
    match scale() {
        Scale::Fast => fast,
        Scale::Default => default,
        Scale::Paper => paper,
    }
}

/// Append a JSON line to target/bench_results/<bench>.jsonl.
pub fn record(bench: &str, json_line: &str) {
    let dir = std::path::Path::new("target/bench_results");
    std::fs::create_dir_all(dir).ok();
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(dir.join(format!("{bench}.jsonl")))
    {
        let _ = writeln!(f, "{json_line}");
    }
}

/// Fixed-width table writer.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    pub fn new(headers: &[(&str, usize)]) -> Table {
        let mut line = String::new();
        let mut widths = Vec::new();
        for (h, w) in headers {
            line.push_str(&format!("{h:>w$} ", w = *w));
            widths.push(*w);
        }
        println!("{line}");
        println!("{}", "-".repeat(line.len()));
        Table { widths }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (c, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{c:>w$} ", w = *w));
        }
        println!("{line}");
    }
}

/// fmt helpers
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

pub fn secs(v: f64) -> String {
    if v >= 60.0 {
        format!("{:.1}min", v / 60.0)
    } else if v >= 1.0 {
        format!("{v:.1}s")
    } else {
        format!("{:.0}ms", v * 1e3)
    }
}
