//! F-SERVE — §4.2's prediction path under load: QPS and latency
//! percentiles of the TCP serving stack, batched vs unbatched, at several
//! client concurrencies.

#[path = "common.rs"]
mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use common::{by_scale, f, record, Table};
use wlsh_krr::api::MethodSpec;
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::{serve, ServerConfig, Trainer};
use wlsh_krr::data::synthetic_by_name;
use wlsh_krr::util::json::{Json, JsonWriter};

fn run_load(
    model: Arc<wlsh_krr::coordinator::TrainedModel>,
    d: usize,
    rows: &[f32],
    nq: usize,
    clients: usize,
    requests: usize,
    max_batch: usize,
) -> (f64, f64, f64, f64) {
    let (tx, rx) = std::sync::mpsc::channel();
    let scfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch,
        linger: Duration::from_micros(200),
        workers: 1,
    };
    let m = model.clone();
    let server = std::thread::spawn(move || serve(m, scfg, Some(tx)).unwrap());
    let addr = rx.recv().unwrap();
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = addr.clone();
            let rows = rows;
            scope.spawn(move || {
                let mut conn = TcpStream::connect(&addr).unwrap();
                conn.set_nodelay(true).ok();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                for r in 0..requests {
                    let qi = (c * 7919 + r * 13) % nq;
                    let feats: Vec<String> = rows[qi * d..(qi + 1) * d]
                        .iter()
                        .map(|v| format!("{v}"))
                        .collect();
                    writeln!(conn, "{{\"features\": [{}]}}", feats.join(",")).unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    writeln!(conn, "{{\"cmd\": \"stats\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(&line).unwrap();
    let p50 = stats.get("p50_us").and_then(Json::as_f64).unwrap_or(0.0);
    let p99 = stats.get("p99_us").and_then(Json::as_f64).unwrap_or(0.0);
    writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
    let mut l2 = String::new();
    reader.read_line(&mut l2).unwrap();
    server.join().unwrap();
    ((clients * requests) as f64 / secs, secs, p50, p99)
}

fn main() {
    let mut ds = synthetic_by_name("insurance", Some(by_scale(1000, 4000, 9822)), 7).unwrap();
    ds.standardize();
    let n_train = ds.n * 4 / 5;
    let (train, test) = ds.split(n_train, 8);
    let cfg = KrrConfig {
        method: MethodSpec::Wlsh,
        budget: 250,
        scale: 5.0,
        lambda: 0.5,
        ..Default::default()
    };
    let model = Arc::new(Trainer::new(cfg).train(&train).expect("train"));
    let requests = by_scale(50, 250, 1000);
    println!(
        "=== F-SERVE: serving load (wlsh m=250, d={}, {} req/client) ===\n",
        train.d, requests
    );
    let t = Table::new(&[
        ("clients", 8),
        ("batching", 9),
        ("qps", 9),
        ("p50(us)", 9),
        ("p99(us)", 9),
    ]);
    for clients in [1usize, 4, 8] {
        for (label, max_batch) in [("off", 1), ("on", 64)] {
            let (qps, _secs, p50, p99) = run_load(
                model.clone(),
                train.d,
                &test.x,
                test.n,
                clients,
                requests,
                max_batch,
            );
            t.row(&[
                clients.to_string(),
                label.into(),
                f(qps, 0),
                f(p50, 0),
                f(p99, 0),
            ]);
            record(
                "serve",
                &JsonWriter::object()
                    .field_usize("clients", clients)
                    .field_str("batching", label)
                    .field_f64("qps", qps)
                    .field_f64("p50_us", p50)
                    .field_f64("p99_us", p99)
                    .finish(),
            );
        }
    }
    println!(
        "\nreading: a query costs O(m·d) (hash + bucket lookup against the\n\
         precomputed §4.2 loads), a few hundred µs here. Batching only adds\n\
         value once per-batch fixed costs dominate (e.g. the XLA-backend\n\
         predict path); at native per-query costs the linger time shows up\n\
         directly in p50 — measured honestly above."
    );
}
