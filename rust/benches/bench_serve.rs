//! F-SERVE — §4.2's prediction path under load: sustained QPS and latency
//! percentiles of the worker-pool TCP serving engine at several client
//! concurrencies and worker counts.
//!
//! The tracked metric is `us_per_req` (wall-clock microseconds per
//! request across all clients — inverse throughput, lower is better) so
//! the perf-regression gate needs no direction table. Linger is zero
//! here: this table measures the compute path's scaling with workers, not
//! the batching window (whose latency cost the linger knob makes
//! explicit).

#[path = "common.rs"]
mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use common::{by_scale, f, record, Table};
use wlsh_krr::api::MethodSpec;
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::{serve, ModelRegistry, ServerConfig, Trainer};
use wlsh_krr::data::synthetic_by_name;
use wlsh_krr::util::json::{Json, JsonWriter};

struct LoadResult {
    qps: f64,
    us_per_req: f64,
    p50: f64,
    p99: f64,
}

fn run_load(
    model: Arc<wlsh_krr::coordinator::TrainedModel>,
    d: usize,
    rows: &[f32],
    nq: usize,
    clients: usize,
    requests: usize,
    workers: usize,
) -> LoadResult {
    let (tx, rx) = std::sync::mpsc::channel();
    let scfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 64,
        linger: Duration::ZERO,
        workers,
        queue_depth: 1024,
    };
    let registry = ModelRegistry::single(model);
    let server = std::thread::spawn(move || serve(registry, scfg, Some(tx)).unwrap());
    let addr = rx.recv().unwrap();
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = addr.clone();
            let rows = rows;
            scope.spawn(move || {
                let mut conn = TcpStream::connect(&addr).unwrap();
                conn.set_nodelay(true).ok();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                for r in 0..requests {
                    let qi = (c * 7919 + r * 13) % nq;
                    let feats: Vec<String> = rows[qi * d..(qi + 1) * d]
                        .iter()
                        .map(|v| format!("{v}"))
                        .collect();
                    writeln!(conn, "{{\"features\": [{}]}}", feats.join(",")).unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    writeln!(conn, "{{\"cmd\": \"stats\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(&line).unwrap();
    let p50 = stats.get("p50_us").and_then(Json::as_f64).unwrap_or(0.0);
    let p99 = stats.get("p99_us").and_then(Json::as_f64).unwrap_or(0.0);
    writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
    let mut l2 = String::new();
    reader.read_line(&mut l2).unwrap();
    server.join().unwrap();
    let total = (clients * requests) as f64;
    LoadResult { qps: total / secs, us_per_req: secs * 1e6 / total, p50, p99 }
}

fn main() {
    let mut ds = synthetic_by_name("insurance", Some(by_scale(1000, 4000, 9822)), 7).unwrap();
    ds.standardize();
    let n_train = ds.n * 4 / 5;
    let (train, test) = ds.split(n_train, 8);
    let cfg = KrrConfig {
        method: MethodSpec::Wlsh,
        budget: 250,
        scale: 5.0,
        lambda: 0.5,
        ..Default::default()
    };
    let model = Arc::new(Trainer::new(cfg).train(&train).expect("train"));
    let requests = by_scale(50, 250, 1000);
    println!(
        "=== F-SERVE: worker-pool serving engine (wlsh m=250, d={}, {} req/client) ===\n",
        train.d, requests
    );
    let t = Table::new(&[
        ("clients", 8),
        ("workers", 8),
        ("qps", 9),
        ("us/req", 9),
        ("p50(us)", 9),
        ("p99(us)", 9),
    ]);
    let mut qps_1w_8c = 0.0f64;
    let mut qps_4w_8c = 0.0f64;
    for clients in [1usize, 4, 8] {
        for workers in [1usize, 4] {
            let r = run_load(
                model.clone(),
                train.d,
                &test.x,
                test.n,
                clients,
                requests,
                workers,
            );
            if clients == 8 && workers == 1 {
                qps_1w_8c = r.qps;
            }
            if clients == 8 && workers == 4 {
                qps_4w_8c = r.qps;
            }
            t.row(&[
                clients.to_string(),
                workers.to_string(),
                f(r.qps, 0),
                f(r.us_per_req, 0),
                f(r.p50, 0),
                f(r.p99, 0),
            ]);
            record(
                "serve",
                &JsonWriter::object()
                    .field_usize("clients", clients)
                    .field_usize("workers", workers)
                    .field_f64("qps", r.qps)
                    .field_f64("us_per_req", r.us_per_req)
                    .field_f64("p50_us", r.p50)
                    .field_f64("p99_us", r.p99)
                    .finish(),
            );
        }
    }
    if qps_1w_8c > 0.0 {
        println!(
            "\nworkers=4 vs workers=1 at 8 clients: {:.2}x sustained throughput",
            qps_4w_8c / qps_1w_8c
        );
    }
    println!(
        "\nreading: a query costs O(m·d) (hash + bucket lookup against the\n\
         precomputed §4.2 loads). One dispatcher thread serializes that\n\
         work; the pool's shared queue lets `workers` batcher threads hash\n\
         concurrent clients' rows in parallel, so throughput scales with\n\
         cores until the accept/JSON path saturates."
    );
}
