#!/usr/bin/env bash
# Convert the JSONL capture that rust/benches/common.rs appends under
# rust/target/bench_results/ into the committed BENCH_*.json baseline
# format (see README "Performance tracking").
#
# Usage: scripts/bench_baseline.sh [results_dir] [out.json]
#
# Tracked metrics are flat "<bench>.<field>.<scope>" keys where LOWER IS
# ALWAYS BETTER (seconds or microseconds; plus the deterministic OSE eps
# accuracy series), so the regression checker needs no per-metric
# direction table. Only our own machine-generated flat JSONL is parsed —
# a one-line awk field extractor is enough, no JSON library needed.
set -euo pipefail

results_dir="${1:-rust/target/bench_results}"
out="${2:-BENCH.json}"
scale="${BENCH_SCALE:-fast}"

# num <file> — emit "key value" pairs per line for every line of the JSONL
extract() {
    awk '
    function num(line, key,    re, m) {
        re = "\"" key "\":[-+0-9.eE]+"
        if (match(line, re)) {
            m = substr(line, RSTART, RLENGTH)
            sub(/^[^:]*:/, "", m)
            return m
        }
        return ""
    }
    function str(line, key,    re, m) {
        re = "\"" key "\":\"[^\"]*\""
        if (match(line, re)) {
            m = substr(line, RSTART, RLENGTH)
            sub(/^[^:]*:"/, "", m)
            sub(/"$/, "", m)
            return m
        }
        return ""
    }
    FILENAME ~ /matvec\.jsonl$/ {
        series = str($0, "series")
        if (series == "") {
            n = num($0, "n")
            if (n == "") next
            if ((v = num($0, "wlsh_secs")) != "")          print "matvec.wlsh_secs.n" n, v
            if ((v = num($0, "wlsh_unfused_secs")) != "")  print "matvec.wlsh_unfused_secs.n" n, v
            if ((v = num($0, "rff_secs")) != "")           print "matvec.rff_secs.n" n, v
            if ((v = num($0, "wlsh_build_secs")) != "")    print "matvec.wlsh_build_secs.n" n, v
        } else if (series == "parallel_vs_serial") {
            n = num($0, "n"); m = num($0, "m")
            if ((v = num($0, "serial_secs")) != "")    print "matvec.serial_secs.n" n ".m" m, v
            if ((v = num($0, "parallel_secs")) != "")  print "matvec.parallel_secs.n" n ".m" m, v
        } else if (series == "sparse_stream_build") {
            n = num($0, "n")
            if ((v = num($0, "wlsh_sparse_secs")) != "") print "matvec.wlsh_sparse_secs.n" n, v
            if ((v = num($0, "rff_sparse_secs")) != "")  print "matvec.rff_sparse_secs.n" n, v
        } else if (series == "simd") {
            # scalar-reference vs vectorized kernel timings at the largest
            # table n; the on/off pair is captured so a baseline diff shows
            # whether a regression is the kernel or the dispatch
            n = num($0, "n")
            if (n == "") next
            if ((v = num($0, "wlsh_matvec_on_secs")) != "")    print "simd.wlsh_matvec_on_secs.n" n, v
            if ((v = num($0, "wlsh_matvec_off_secs")) != "")   print "simd.wlsh_matvec_off_secs.n" n, v
            if ((v = num($0, "bucket_loads_on_secs")) != "")   print "simd.bucket_loads_on_secs.n" n, v
            if ((v = num($0, "bucket_loads_off_secs")) != "")  print "simd.bucket_loads_off_secs.n" n, v
            if ((v = num($0, "rff_featurize_on_secs")) != "")  print "simd.rff_featurize_on_secs.n" n, v
            if ((v = num($0, "rff_featurize_off_secs")) != "") print "simd.rff_featurize_off_secs.n" n, v
        } else if (series == "sharded_solve") {
            # end-to-end train seconds through the sharded (wire-protocol)
            # path vs the single-process solve, keyed by shard count
            s = num($0, "shards")
            if (s == "") next
            if ((v = num($0, "sharded_secs")) != "")     print "solve.sharded_secs.s" s, v
            if ((v = num($0, "local_solve_secs")) != "") print "solve.local_secs.s" s, v
        } else if (series == "warm_vs_cold_resolve") {
            # CG iterations of the warm-started online re-solve vs the cold
            # solve on the identical appended system (deterministic: fixed
            # seeds and reduction order; fewer iterations is better)
            if ((v = num($0, "warm_iters")) != "")  print "solve.warm_iters", v
            if ((v = num($0, "cold_iters")) != "")  print "solve.cold_iters", v
            if ((v = num($0, "update_secs")) != "") print "solve.update_secs", v
        }
        next
    }
    FILENAME ~ /ose\.jsonl$/ {
        # deterministic (fixed seeds): eps is a tracked accuracy metric
        series = str($0, "series")
        if (series == "eps_vs_m") {
            m = num($0, "m")
            if (m != "" && (v = num($0, "eps")) != "") print "ose.eps.m" m, v
        } else if (series == "eps_vs_kept") {
            # importance-sampled spectral error keyed by sampling x pool m
            s = str($0, "sampling"); m = num($0, "pool_m")
            if (s != "" && m != "" && (v = num($0, "eps")) != "")
                print "ose.eps_kept." s ".m" m, v
        }
        next
    }
    FILENAME ~ /ablation\.jsonl$/ {
        # accuracy-vs-m under importance sampling (deterministic seeds):
        # the series the CI sampling smoke gates on — leverage at 0.75m
        # must track uniform at the full m
        if (str($0, "series") != "rmse_at_m") next
        s = str($0, "sampling"); m = num($0, "pool_m")
        if (s == "" || m == "") next
        if ((v = num($0, "rmse")) != "") print "ablation.rmse_at_m." s ".m" m, v
        next
    }
    FILENAME ~ /serve\.jsonl$/ {
        # worker-pool engine capture: keyed by client count x worker count;
        # us_per_req is inverse throughput, so every key stays lower-is-better
        c = num($0, "clients"); w = num($0, "workers")
        if (c == "" || w == "") next
        if ((v = num($0, "us_per_req")) != "") print "serve.us_per_req.c" c ".w" w, v
        if ((v = num($0, "p50_us")) != "")     print "serve.p50_us.c" c ".w" w, v
        if ((v = num($0, "p99_us")) != "")     print "serve.p99_us.c" c ".w" w, v
        next
    }
    ' "$@"
}

files=$(find "$results_dir" -name '*.jsonl' 2>/dev/null | sort || true)
if [ -z "$files" ]; then
    echo "error: no *.jsonl under $results_dir — run the benches first" >&2
    exit 1
fi

commit=$(git rev-parse --short HEAD 2>/dev/null || echo unknown)

# ISA the bench process dispatched to (recorded by bench_matvec's simd
# series) — kept as a header field, not a metric, so baselines from
# different runner classes are flagged as incomparable by the checker.
# shellcheck disable=SC2086
isa=$(grep -ho '"isa":"[^"]*"' $files 2>/dev/null | head -n1 | sed 's/.*:"//; s/"//')

{
    printf '{\n'
    printf '  "format": 1,\n'
    printf '  "commit": "%s",\n' "$commit"
    printf '  "isa": "%s",\n' "${isa:-unknown}"
    printf '  "scale": "%s",\n' "$scale"
    printf '  "metrics": {\n'
    # unique by metric key (first occurrence wins), sorted for stable diffs
    # shellcheck disable=SC2086
    extract $files | sort -u -k1,1 | awk '
        NR > 1 { printf ",\n" }
        { printf "    \"%s\": %s", $1, $2 }
        END { if (NR > 0) printf "\n" }
    '
    printf '  }\n'
    printf '}\n'
} > "$out"

count=$(extract $files | sort -u -k1,1 | wc -l)
echo "wrote $out ($count tracked metrics, scale=$scale, commit=$commit)"
