#!/usr/bin/env bash
# Gate CI on perf regressions: compare a freshly generated BENCH json
# (scripts/bench_baseline.sh output) against the newest committed
# bench/BENCH_*.json baseline. Every tracked metric is lower-is-better;
# any metric that got more than THRESHOLD× worse fails the job. Skips
# cleanly (exit 0) when no committed baseline exists yet.
#
# The default threshold is 1.25× — tightened from the original 1.5× once
# the percentile indexing was fixed to nearest-rank (honest tails) and
# the hot paths were vectorized (lower variance at the same wall-time).
#
# Usage: scripts/check_bench_regression.sh <current.json> [baseline_dir]
set -euo pipefail

cur="${1:?usage: check_bench_regression.sh <current.json> [baseline_dir]}"
dir="${2:-bench}"
threshold="${BENCH_REGRESSION_THRESHOLD:-1.25}"

[ -f "$cur" ] || { echo "error: $cur not found" >&2; exit 1; }

prev=$(ls "$dir"/BENCH_*.json 2>/dev/null | sort -V | tail -n 1 || true)
if [ -z "$prev" ]; then
    echo "no committed baseline under $dir/ — skipping regression gate"
    exit 0
fi

# Surface which instruction set each side ran with: a scalar-vs-AVX2
# mismatch makes ratios meaningless, so print it next to the verdict.
isa_of() {
    sed -n 's/^[[:space:]]*"isa":[[:space:]]*"\([^"]*\)".*$/\1/p' "$1" | head -n1
}
cur_isa=$(isa_of "$cur"); prev_isa=$(isa_of "$prev")
echo "comparing $cur against baseline $prev (threshold ${threshold}x)"
echo "detected ISA: current=${cur_isa:-unknown} baseline=${prev_isa:-unknown}"
if [ -n "$cur_isa" ] && [ -n "$prev_isa" ] && [ "$cur_isa" != "$prev_isa" ]; then
    echo "warning: ISA mismatch — timings may not be comparable" >&2
fi

# Metric lines are exactly those the generator writes:  "a.b.c": <num>
# (only metric keys contain a '.', so format/commit/scale never match).
metrics() {
    sed -n 's/^[[:space:]]*"\([^"]*\.[^"]*\)":[[:space:]]*\([-+0-9.eE]*\).*$/\1 \2/p' "$1"
}

metrics "$prev" > /tmp/bench_prev.$$
metrics "$cur" > /tmp/bench_cur.$$
trap 'rm -f /tmp/bench_prev.$$ /tmp/bench_cur.$$' EXIT

fails=$(
    awk -v threshold="$threshold" '
        NR == FNR { prev[$1] = $2; next }
        {
            if (!($1 in prev)) { printf "  new metric (not gated): %s\n", $1 > "/dev/stderr"; next }
            seen[$1] = 1
            p = prev[$1] + 0; c = $2 + 0
            if (p <= 0) next
            ratio = c / p
            if (ratio > threshold)
                printf "REGRESSION %s: %.3g -> %.3g (%.2fx)\n", $1, p, c, ratio
            else
                printf "  ok %s: %.3g -> %.3g (%.2fx)\n", $1, p, c, ratio > "/dev/stderr"
        }
        END {
            for (k in prev)
                if (!(k in seen))
                    printf "  missing metric (was tracked): %s\n", k > "/dev/stderr"
        }
    ' /tmp/bench_prev.$$ /tmp/bench_cur.$$
)

if [ -n "$fails" ]; then
    echo "$fails"
    echo "perf regression gate FAILED (>${threshold}x slowdown on tracked metrics)" >&2
    exit 1
fi
echo "perf regression gate passed"
