//! Serving demo: trains a model, starts the worker-pool TCP JSON-lines
//! server, fires a concurrent client workload (single + batched requests)
//! through it, and prints the latency report.
//!
//! Run with:
//!   cargo run --release --example serve [-- --clients 4 --requests 400 --workers 4]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use wlsh_krr::api::{KrrError, KrrModel, MethodSpec};
use wlsh_krr::coordinator::{serve, ModelRegistry, ServerConfig};
use wlsh_krr::data::synthetic_by_name;
use wlsh_krr::util::cli::Args;
use wlsh_krr::util::json::Json;

fn main() -> Result<(), KrrError> {
    let args = Args::from_env();
    let clients = args.get_usize("clients", 4);
    let requests = args.get_usize("requests", 400);
    let workers = args.get_usize("workers", wlsh_krr::util::par::num_threads());

    let mut ds = synthetic_by_name("insurance", Some(3000), 7).expect("dataset");
    ds.standardize();
    let (train, test) = ds.split(2400, 8);
    println!("training wlsh(m=250) on insurance-synthetic (n={}, d={})...", train.n, train.d);
    let model = Arc::new(
        KrrModel::builder()
            .method(MethodSpec::Wlsh)
            .budget(250)
            .scale(5.0)
            .lambda(0.5)
            .fit(&train)?,
    );

    let (tx, rx) = std::sync::mpsc::channel();
    let scfg = ServerConfig {
        addr: args.get_or("addr", "127.0.0.1:0").to_string(),
        max_batch: args.get_usize("max-batch", 64),
        linger: Duration::from_micros(args.get_usize("linger-us", 300) as u64),
        workers,
        queue_depth: args.get_usize("queue-depth", 1024),
    };
    let d = model.dim();
    let registry = ModelRegistry::single(model);
    let server = std::thread::spawn(move || serve(registry, scfg, Some(tx)).unwrap());
    let addr = rx.recv().unwrap();
    println!(
        "serving on {addr} with {workers} workers; {clients} clients × {requests} requests each"
    );

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let rows: Vec<f32> = test.x.clone();
        let nq = test.n;
        handles.push(std::thread::spawn(move || {
            let mut conn = TcpStream::connect(&addr).unwrap();
            conn.set_nodelay(true).ok();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let row = |qi: usize| {
                let feats: Vec<String> =
                    rows[qi * d..(qi + 1) * d].iter().map(|v| format!("{v}")).collect();
                format!("[{}]", feats.join(","))
            };
            for r in 0..requests {
                if r % 5 == 4 {
                    // every fifth request: a batch of 4 rows, one reply per row
                    let idxs: Vec<usize> = (0..4).map(|k| (c * 7919 + r + k) % nq).collect();
                    let rows_json: Vec<String> = idxs.iter().map(|&qi| row(qi)).collect();
                    writeln!(conn, "{{\"batch\": [{}]}}", rows_json.join(",")).unwrap();
                    for _ in &idxs {
                        let mut line = String::new();
                        reader.read_line(&mut line).unwrap();
                        assert!(line.contains("pred"), "bad response: {line}");
                    }
                } else {
                    let qi = (c * 7919 + r) % nq;
                    writeln!(conn, "{{\"features\": {}}}", row(qi)).unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    assert!(line.contains("pred"), "bad response: {line}");
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let total = clients * requests;
    let secs = t0.elapsed().as_secs_f64();

    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    writeln!(conn, "{{\"cmd\": \"stats\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(&line).unwrap();
    println!(
        "{total} requests in {secs:.2}s = {:.0} req/s | served {} rows, rejected {} | \
         latency p50 {:.0}us p95 {:.0}us p99 {:.0}us",
        total as f64 / secs,
        stats.get("served").and_then(Json::as_usize).unwrap_or(0),
        stats.get("rejected").and_then(Json::as_usize).unwrap_or(0),
        stats.get("p50_us").and_then(Json::as_f64).unwrap_or(0.0),
        stats.get("p95_us").and_then(Json::as_f64).unwrap_or(0.0),
        stats.get("p99_us").and_then(Json::as_f64).unwrap_or(0.0),
    );
    writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    server.join().unwrap();
    Ok(())
}
