//! Serving demo: trains a model, starts the worker-pool TCP JSON-lines
//! server, fires a concurrent client workload (single + batched requests)
//! through it, and prints the latency report. The client side speaks the
//! typed wire protocol (`wlsh_krr::coordinator::proto`) — requests are
//! built as [`Request`] values and replies parsed as [`Response`]s, the
//! same types the server itself uses.
//!
//! Run with:
//!   cargo run --release --example serve [-- --clients 4 --requests 400 --workers 4]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use wlsh_krr::api::{KrrError, KrrModel, MethodSpec};
use wlsh_krr::coordinator::proto::{Request, Response};
use wlsh_krr::coordinator::{serve, ModelRegistry, ServerConfig};
use wlsh_krr::data::synthetic_by_name;
use wlsh_krr::util::cli::Args;

fn main() -> Result<(), KrrError> {
    let args = Args::from_env();
    let clients = args.get_usize("clients", 4);
    let requests = args.get_usize("requests", 400);
    let workers = args.get_usize("workers", wlsh_krr::util::par::num_threads());

    let mut ds = synthetic_by_name("insurance", Some(3000), 7).expect("dataset");
    ds.standardize();
    let (train, test) = ds.split(2400, 8);
    println!("training wlsh(m=250) on insurance-synthetic (n={}, d={})...", train.n, train.d);
    let model = Arc::new(
        KrrModel::builder()
            .method(MethodSpec::Wlsh)
            .budget(250)
            .scale(5.0)
            .lambda(0.5)
            .fit(&train)?,
    );

    let (tx, rx) = std::sync::mpsc::channel();
    let scfg = ServerConfig {
        addr: args.get_or("addr", "127.0.0.1:0").to_string(),
        max_batch: args.get_usize("max-batch", 64),
        linger: Duration::from_micros(args.get_usize("linger-us", 300) as u64),
        workers,
        queue_depth: args.get_usize("queue-depth", 1024),
    };
    let d = model.dim();
    let registry = ModelRegistry::single(model);
    let server = std::thread::spawn(move || serve(registry, scfg, Some(tx)).unwrap());
    let addr = rx.recv().unwrap();
    println!(
        "serving on {addr} with {workers} workers; {clients} clients × {requests} requests each"
    );

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let rows: Vec<f32> = test.x.clone();
        let nq = test.n;
        handles.push(std::thread::spawn(move || {
            let mut conn = TcpStream::connect(&addr).unwrap();
            conn.set_nodelay(true).ok();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let row = |qi: usize| rows[qi * d..(qi + 1) * d].to_vec();
            let mut expect_pred = |reader: &mut BufReader<TcpStream>| {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                match Response::parse(line.trim_end()) {
                    Ok(Response::Pred(_)) => {}
                    other => panic!("bad response: {other:?} ({line})"),
                }
            };
            for r in 0..requests {
                if r % 5 == 4 {
                    // every fifth request: a batch of 4 rows, one reply per row
                    let idxs: Vec<usize> = (0..4).map(|k| (c * 7919 + r + k) % nq).collect();
                    let req = Request::Batch {
                        rows: idxs.iter().map(|&qi| row(qi)).collect(),
                        model: None,
                    };
                    writeln!(conn, "{}", req.to_line()).unwrap();
                    for _ in &idxs {
                        expect_pred(&mut reader);
                    }
                } else {
                    let qi = (c * 7919 + r) % nq;
                    let req = Request::Predict { features: row(qi), model: None };
                    writeln!(conn, "{}", req.to_line()).unwrap();
                    expect_pred(&mut reader);
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let total = clients * requests;
    let secs = t0.elapsed().as_secs_f64();

    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    writeln!(conn, "{}", Request::Stats.to_line()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let stats = match Response::parse(line.trim_end()) {
        Ok(Response::Stats(s)) => s,
        other => panic!("bad stats reply: {other:?} ({line})"),
    };
    println!(
        "{total} requests in {secs:.2}s = {:.0} req/s | served {} rows, rejected {} | \
         latency p50 {:.0}us p95 {:.0}us p99 {:.0}us",
        total as f64 / secs,
        stats.served,
        stats.rejected,
        stats.p50_us,
        stats.p95_us,
        stats.p99_us,
    );
    writeln!(conn, "{}", Request::Shutdown.to_line()).unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    server.join().unwrap();
    Ok(())
}
