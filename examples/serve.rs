//! Serving demo: trains a model, starts the TCP JSON-lines server, fires a
//! concurrent client workload through it, and prints the latency report.
//!
//! Run with:  cargo run --release --example serve [-- --clients 4 --requests 400]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use wlsh_krr::api::{KrrError, KrrModel, MethodSpec};
use wlsh_krr::coordinator::{serve, ServerConfig};
use wlsh_krr::data::synthetic_by_name;
use wlsh_krr::util::cli::Args;
use wlsh_krr::util::json::Json;

fn main() -> Result<(), KrrError> {
    let args = Args::from_env();
    let clients = args.get_usize("clients", 4);
    let requests = args.get_usize("requests", 400);

    let mut ds = synthetic_by_name("insurance", Some(3000), 7).expect("dataset");
    ds.standardize();
    let (train, test) = ds.split(2400, 8);
    println!("training wlsh(m=250) on insurance-synthetic (n={}, d={})...", train.n, train.d);
    let model = Arc::new(
        KrrModel::builder()
            .method(MethodSpec::Wlsh)
            .budget(250)
            .scale(5.0)
            .lambda(0.5)
            .fit(&train)?,
    );

    let (tx, rx) = std::sync::mpsc::channel();
    let scfg = ServerConfig {
        addr: args.get_or("addr", "127.0.0.1:0").to_string(),
        max_batch: args.get_usize("max-batch", 64),
        linger: Duration::from_micros(args.get_usize("linger-us", 300) as u64),
        workers: 1,
    };
    let d = model.dim();
    let m = model.clone();
    let server = std::thread::spawn(move || serve(m, scfg, Some(tx)).unwrap());
    let addr = rx.recv().unwrap();
    println!("serving on {addr}; {clients} clients × {requests} requests each");

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let rows: Vec<f32> = test.x.clone();
        let nq = test.n;
        handles.push(std::thread::spawn(move || {
            let mut conn = TcpStream::connect(&addr).unwrap();
            conn.set_nodelay(true).ok();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            for r in 0..requests {
                let qi = (c * 7919 + r) % nq;
                let feats: Vec<String> =
                    rows[qi * d..(qi + 1) * d].iter().map(|v| format!("{v}")).collect();
                writeln!(conn, "{{\"features\": [{}]}}", feats.join(",")).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                assert!(line.contains("pred"), "bad response: {line}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let total = clients * requests;
    let secs = t0.elapsed().as_secs_f64();

    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    writeln!(conn, "{{\"cmd\": \"stats\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(&line).unwrap();
    println!(
        "{total} requests in {secs:.2}s = {:.0} qps | latency p50 {:.0}us p90 {:.0}us p99 {:.0}us",
        total as f64 / secs,
        stats.get("p50_us").and_then(Json::as_f64).unwrap_or(0.0),
        stats.get("p90_us").and_then(Json::as_f64).unwrap_or(0.0),
        stats.get("p99_us").and_then(Json::as_f64).unwrap_or(0.0),
    );
    writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    server.join().unwrap();
    Ok(())
}
