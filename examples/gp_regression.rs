//! Gaussian-process regression with the WLSH kernel family (paper §5,
//! Table 1 setting): sample a GP path with a chosen covariance, fit KRR
//! with each candidate kernel — including the paper's smooth WLSH kernel
//! f = (rect*rect_{1/4}*rect_{1/4})(2x), p = Gamma(7,1) — and compare
//! test RMSE.
//!
//! Run with:  cargo run --release --example gp_regression -- --cov se --dim 5

use wlsh_krr::api::{KernelSpec, KrrError, KrrModel, MethodSpec};
use wlsh_krr::data::{rmse, Dataset};
use wlsh_krr::gp::sample_gp_exact;
use wlsh_krr::util::cli::Args;
use wlsh_krr::util::rng::Pcg64;

fn main() -> Result<(), KrrError> {
    let args = Args::from_env();
    let cov = args.get_or("cov", "se");
    let d = args.get_usize("dim", 5);
    let n = args.get_usize("n", 1200);
    let noise = args.get_f64("noise", 0.05);
    let seed = args.get_usize("seed", 1) as u64;

    // "laplace" | "se" | "matern" parse through the one kernel grammar; a
    // typo exits with an UnknownKernel error instead of a panic.
    let covariance = cov.parse::<KernelSpec>()?.build();

    // Sample η ~ GP(0, cov) at n uniform points in [0,1]^d (paper §5).
    let mut rng = Pcg64::new(seed, 0);
    let pts: Vec<f32> = (0..n * d).map(|_| rng.uniform() as f32).collect();
    println!("sampling GP({cov}) at {n} points in [0,1]^{d} ...");
    let path = sample_gp_exact(&covariance, &pts, d, &mut rng).expect("GP sample");
    let y: Vec<f64> = path.iter().map(|v| v + noise * rng.normal()).collect();
    let ds = Dataset::new(&format!("gp-{cov}-d{d}"), pts, y, d);
    let (train, test) = ds.split(n * 3 / 4, seed + 1);

    println!(
        "{:<28} {:>8} {:>10} {:>8}",
        "regression kernel", "rmse", "solve(s)", "iters"
    );
    for (label, method, bucket, shape) in [
        ("Laplace", "exact-laplace", "rect", 2.0),
        ("Squared exponential", "exact-se", "rect", 2.0),
        ("Matern nu=5/2", "exact-matern", "rect", 2.0),
        ("WLSH k_{f,p} (smooth2, G7)", "exact-wlsh", "smooth2", 7.0),
    ] {
        let method: MethodSpec = method.parse()?;
        let model = KrrModel::builder()
            .method(method)
            .bucket(bucket)
            .gamma_shape(shape)
            .scale(args.get_f64("scale", 1.0))
            .lambda(args.get_f64("lambda", 0.02))
            .cg_max_iters(400)
            .cg_tol(1e-7)
            .fit(&train)?;
        let err = rmse(&model.predict(&test.x), &test.y);
        println!(
            "{label:<28} {err:>8.4} {:>10.2} {:>8}",
            model.report.solve_secs, model.report.cg_iters
        );
    }
    Ok(())
}
