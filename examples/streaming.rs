//! Out-of-core training demo: generate an on-disk CSV (streaming writes,
//! never holding the matrix), then train a KRR model from it chunk by
//! chunk through the `DataSource` pipeline — peak memory stays at
//! O(chunk + sketch) no matter how large the file grows, so with the
//! defaults scaled up the dataset can exceed the process's memory budget
//! (CI runs this under `ulimit -v` with an address-space cap *below* the
//! file's in-memory footprint).
//!
//! Run with:  cargo run --release --example streaming
//!
//! Env knobs: STREAM_ROWS (default 60000), STREAM_DIM (default 24),
//! STREAM_BUDGET (RFF features, default 32), STREAM_CHUNK (default 8192),
//! STREAM_CG_ITERS (default 15), STREAM_PATH (default: target dir temp).

use std::io::Write;
use std::time::Instant;

use wlsh_krr::api::KrrModel;
use wlsh_krr::data::{head_sample, rmse, CsvSource, DataSource, Standardizer};
use wlsh_krr::util::mem::peak_rss_bytes;
use wlsh_krr::util::rng::Pcg64;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Stream one synthetic row (teacher: sparse linear + one kink) from a
/// per-row RNG, so generation needs O(d) memory total.
fn gen_row(rng: &mut Pcg64, d: usize, row: &mut Vec<f64>) -> f64 {
    row.clear();
    let mut y = 0.0;
    for j in 0..d {
        let v = rng.normal();
        row.push(v);
        // a sparse teacher: every 4th coordinate matters
        if j % 4 == 0 {
            let w = 1.0 / (1.0 + j as f64 / 4.0);
            y += w * (v + 0.5 * (v - 0.3).abs());
        }
    }
    y + 0.1 * rng.normal()
}

fn main() {
    let rows = env_usize("STREAM_ROWS", 60_000);
    let d = env_usize("STREAM_DIM", 24);
    let budget = env_usize("STREAM_BUDGET", 32);
    let chunk = env_usize("STREAM_CHUNK", 8192);
    let cg_iters = env_usize("STREAM_CG_ITERS", 15);
    let path = std::env::var("STREAM_PATH").unwrap_or_else(|_| {
        std::env::temp_dir().join("wlsh_streaming_demo.csv").to_string_lossy().into_owned()
    });

    println!("=== stage 1: generate on-disk CSV (streaming writes) ===");
    let t0 = Instant::now();
    {
        let file = std::fs::File::create(&path).expect("create csv");
        let mut w = std::io::BufWriter::new(file);
        let mut row = Vec::with_capacity(d);
        let mut line = String::new();
        for i in 0..rows {
            let mut rng = Pcg64::new(0x5eed, i as u64 + 1);
            let y = gen_row(&mut rng, d, &mut row);
            line.clear();
            for v in &row {
                line.push_str(&format!("{v:.5},"));
            }
            line.push_str(&format!("{y:.5}\n"));
            w.write_all(line.as_bytes()).expect("write row");
        }
        w.flush().expect("flush csv");
    }
    let file_bytes = std::fs::metadata(&path).expect("stat csv").len() as usize;
    // what loading it whole would cost: the text itself + the f64 parse
    // rows + the f32 feature matrix, all resident at once
    let in_memory_estimate = file_bytes + rows * ((d + 1) * 8 + 32) + rows * d * 4;
    println!(
        "wrote {path}: {rows} rows x {d} features, {:.1} MB on disk ({:.1}s)",
        file_bytes as f64 / 1e6,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "naive in-memory load would need ~{:.0} MB resident",
        in_memory_estimate as f64 / 1e6
    );

    println!("\n=== stage 2: streamed standardize + train (chunk={chunk}) ===");
    let src = CsvSource::open(&path, -1).expect("open csv");
    assert_eq!(src.dim(), d);
    let t1 = Instant::now();
    let standardizer = Standardizer::fit(&src, chunk).expect("fit standardizer");
    println!("standardizer fitted in {:.1}s (one Welford pass)", t1.elapsed().as_secs_f64());
    let view = standardizer.source(&src);
    // bandwidth ≈ the median pairwise distance of standardized data
    // (‖x−x′‖² ≈ 2d), so the SE kernel keeps mass at this dimensionality
    let scale = (2.0 * d as f64).sqrt();
    let model = KrrModel::builder()
        .method("rff")
        .budget(budget)
        .scale(scale)
        .lambda(0.5)
        .cg_max_iters(cg_iters)
        .chunk_rows(chunk)
        .fit_source(&view)
        .expect("streamed fit");
    let rep = &model.report;
    println!(
        "trained {} on {} rows: build {:.1}s ({:.0} rows/s), solve {:.1}s ({} iters)",
        rep.operator,
        model.beta.len(),
        rep.build_secs,
        rep.rows_per_sec,
        rep.solve_secs,
        rep.cg_iters
    );

    println!("\n=== stage 3: memory + quality report ===");
    let sample = head_sample(&view, 1000, chunk).expect("eval sample");
    let pred = model.predict(&sample.x);
    let err = rmse(&pred, &sample.y);
    let mean_err = rmse(&vec![0.0; sample.n], &sample.y);
    println!("train-sample rmse {err:.4} (mean predictor {mean_err:.4})");
    println!("operator memory: {:.1} MB", rep.memory_bytes as f64 / 1e6);
    match peak_rss_bytes() {
        Some(peak) => {
            let verdict = if peak < in_memory_estimate {
                "streaming won"
            } else {
                "dataset too small to tell"
            };
            println!(
                "peak RSS {:.0} MB vs ~{:.0} MB for the naive in-memory load ({verdict})",
                peak as f64 / 1e6,
                in_memory_estimate as f64 / 1e6,
            );
        }
        None => println!("peak RSS unavailable on this platform"),
    }
    // smoke gate: the streamed solve must be sane (finite, not diverging);
    // statistical quality is asserted by the test suite, not this example
    assert!(err.is_finite(), "streamed model produced non-finite error");
    assert!(
        err < 1.05 * mean_err,
        "streamed model diverged: rmse {err} vs mean predictor {mean_err}"
    );
    std::fs::remove_file(&path).ok();
}
