//! End-to-end driver — the repo's headline run (recorded in
//! EXPERIMENTS.md): the full three-layer pipeline on the covtype-scale
//! synthetic workload.
//!
//!   data → standardize → WLSH sketch (m instances, sharded build) →
//!   CG solve with convergence log → test RMSE → RFF baseline at the
//!   paper's D → batched serving smoke with latency percentiles.
//!
//! Defaults to n = 100_000 so the run finishes in minutes on one core;
//! pass --paper to use the paper's full n = 581_012 / 500_000-train split.
//!
//! Run with:  cargo run --release --example large_scale [-- --paper]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use wlsh_krr::api::MethodSpec;
use wlsh_krr::config::KrrConfig;
use wlsh_krr::coordinator::{serve, ModelRegistry, ServerConfig, Trainer};
use wlsh_krr::data::{rmse, synthetic_by_name};
use wlsh_krr::solver::{solve_krr, CgOptions};
use wlsh_krr::util::cli::Args;
use wlsh_krr::util::json::Json;

fn main() {
    let args = Args::from_env();
    let paper = args.get_bool("paper");
    let n_max = if paper { None } else { Some(args.get_usize("n-max", 100_000)) };
    let seed = args.get_usize("seed", 42) as u64;

    println!("=== stage 1: data ===");
    let t0 = Instant::now();
    let mut ds = synthetic_by_name("covtype", n_max, seed).expect("dataset");
    ds.standardize();
    let n_train = (ds.n as f64 * (500_000.0 / 581_012.0)) as usize;
    let (train, test) = ds.split(n_train, seed);
    println!(
        "covtype-synthetic: n={} d={} train={} test={} ({:.1}s)",
        ds.n, ds.d, train.n, test.n, t0.elapsed().as_secs_f64()
    );

    // bandwidths via the median heuristic (L1 for WLSH, L2 for RFF)
    let med_l1 = wlsh_krr::data::median_distance(&train, true, 500, 11);
    let med_l2 = wlsh_krr::data::median_distance(&train, false, 500, 11);
    println!("median distances: L1 {med_l1:.1}, L2 {med_l2:.1}");

    println!("\n=== stage 2: WLSH training (m=50, rect bucket) ===");
    let cfg = KrrConfig {
        method: MethodSpec::Wlsh,
        budget: 50,
        bucket: "rect".parse().expect("bucket"),
        gamma_shape: 2.0,
        scale: med_l1,
        lambda: 0.5,
        cg_max_iters: 60,
        cg_tol: 1e-4,
        workers: args.get_usize("workers", 2),
        seed,
        ..Default::default()
    };
    let trainer = Trainer::new(cfg.clone());
    let t1 = Instant::now();
    let op = trainer.build_operator(&train).expect("build operator");
    let build_secs = t1.elapsed().as_secs_f64();
    println!("sketch built in {build_secs:.1}s ({:.1} MB)", op.memory_bytes() as f64 / 1e6);
    let t2 = Instant::now();
    let cg = solve_krr(
        op.as_ref(),
        &train.y,
        cfg.lambda,
        &CgOptions { max_iters: cfg.cg_max_iters, tol: cfg.cg_tol, verbose: false },
    );
    let solve_secs = t2.elapsed().as_secs_f64();
    println!("CG convergence (rel. residual):");
    for (i, r) in cg.history.iter().enumerate() {
        if i % 5 == 0 || i + 1 == cg.history.len() {
            println!("  iter {:>3}  {r:.3e}", i + 1);
        }
    }
    println!("solved in {solve_secs:.1}s ({} iters, converged={})", cg.iters, cg.converged);
    let wlsh_pred = op.predict(&test.x, &cg.beta);
    let wlsh_rmse = rmse(&wlsh_pred, &test.y);
    println!("WLSH  test RMSE {wlsh_rmse:.4}   total {:.1}s", build_secs + solve_secs);

    println!("\n=== stage 3: RFF baseline (D=1500) ===");
    let rff_cfg = KrrConfig { method: MethodSpec::Rff, budget: 1500, scale: med_l2, ..cfg.clone() };
    let t3 = Instant::now();
    let rff = Trainer::new(rff_cfg).train(&train).expect("train rff");
    let rff_pred = rff.predict(&test.x);
    let rff_rmse = rmse(&rff_pred, &test.y);
    println!(
        "RFF   test RMSE {rff_rmse:.4}   total {:.1}s (build {:.1}s, solve {:.1}s, {} iters)",
        t3.elapsed().as_secs_f64(),
        rff.report.build_secs,
        rff.report.solve_secs,
        rff.report.cg_iters
    );

    println!("\n=== stage 4: serving smoke (batched TCP predictions) ===");
    let model = Arc::new(wlsh_krr::coordinator::TrainedModel::assemble(
        op,
        cg.beta,
        cfg,
        wlsh_krr::coordinator::TrainReport {
            build_secs,
            solve_secs,
            cg_iters: cg.iters,
            cg_rel_residual: cg.rel_residual,
            converged: cg.converged,
            operator: "wlsh".into(),
            precond: "none".into(),
            memory_bytes: 0,
            rows_per_sec: 0.0,
            peak_rss_bytes: 0,
        },
    ));
    let (tx, rx) = std::sync::mpsc::channel();
    let scfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 64,
        linger: Duration::from_micros(300),
        workers: wlsh_krr::util::par::num_threads(),
        queue_depth: 1024,
    };
    let d = model.dim();
    let m = model.clone();
    let server =
        std::thread::spawn(move || serve(ModelRegistry::single(m), scfg, Some(tx)).unwrap());
    let addr = rx.recv().unwrap();
    let n_req = 500.min(test.n);
    let t4 = Instant::now();
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut max_abs_diff = 0.0f64;
    for qi in 0..n_req {
        let feats: Vec<String> = test.x[qi * d..(qi + 1) * d].iter().map(|v| format!("{v}")).collect();
        writeln!(conn, "{{\"features\": [{}]}}", feats.join(",")).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let got = Json::parse(&line).unwrap().get("pred").and_then(Json::as_f64).unwrap();
        max_abs_diff = max_abs_diff.max((got - wlsh_pred[qi]).abs());
    }
    let serve_secs = t4.elapsed().as_secs_f64();
    writeln!(conn, "{{\"cmd\": \"stats\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(&line).unwrap();
    println!(
        "served {n_req} requests in {serve_secs:.2}s ({:.0} qps), p50 {:.0}us p99 {:.0}us, max|Δ| vs direct = {max_abs_diff:.2e}",
        n_req as f64 / serve_secs,
        stats.get("p50_us").and_then(Json::as_f64).unwrap_or(0.0),
        stats.get("p99_us").and_then(Json::as_f64).unwrap_or(0.0),
    );
    writeln!(conn, "{{\"cmd\": \"shutdown\"}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    server.join().unwrap();

    println!("\n=== summary ===");
    println!("n={} d={}  WLSH(m=50) rmse={wlsh_rmse:.4}  RFF(D=1500) rmse={rff_rmse:.4}", ds.n, ds.d);
    println!(
        "paper Table 2 (covtype): WLSH 0.720 / 7.5min   RFF 0.968 / 6min  — expect WLSH < RFF here too"
    );
}
