//! Quickstart: train a WLSH-accelerated KRR model through the typed
//! builder API, evaluate it, and compare against the exact-kernel
//! baseline.
//!
//! Run with:  cargo run --release --example quickstart

use wlsh_krr::api::{BucketSpec, KrrError, KrrModel, MethodSpec};
use wlsh_krr::data::{rmse, synthetic_by_name};

fn main() -> Result<(), KrrError> {
    // 1. Data: the "wine"-shaped synthetic regression task (n=6497, d=11),
    //    standardized features/targets, 4000-row training split as in the
    //    paper's Table 2.
    let mut ds = synthetic_by_name("wine", None, 42).expect("dataset");
    ds.standardize();
    let (train, test) = ds.split(4000, 1);
    println!("dataset: {} (n={}, d={}, test={})", ds.name, train.n, train.d, test.n);

    // 2. WLSH KRR (the paper's method): m = 450 LSH instances, rect bucket
    //    (⇒ Laplace-family kernel), CG on (K̃ + λI)β = y. Every setter is
    //    typed; a misspelled method or bucket would surface here as
    //    Err(KrrError::Unknown...) instead of a panic.
    let model = KrrModel::builder()
        .method(MethodSpec::Wlsh)
        .budget(450)
        .bucket(BucketSpec::Rect)
        .gamma_shape(2.0)
        .scale(3.0)
        .lambda(0.5)
        .fit(&train)?;
    let pred = model.predict(&test.x);
    println!(
        "WLSH   : rmse {:.4}  (build {:.2}s, solve {:.2}s, {} CG iters, {:.1} MB)",
        rmse(&pred, &test.y),
        model.report.build_secs,
        model.report.solve_secs,
        model.report.cg_iters,
        model.report.memory_bytes as f64 / 1e6,
    );

    // 3. Exact Laplace-kernel KRR for reference (O(n²) per CG iteration vs
    //    the sketch's O(n·m)). String specs parse through the same enums:
    //    .method("exact-laplace") == .method(MethodSpec::Exact(...)).
    let exact = KrrModel::builder()
        .method("exact-laplace")
        .scale(3.0)
        .lambda(0.5)
        .fit(&train)?;
    let exact_pred = exact.predict(&test.x);
    println!(
        "exact  : rmse {:.4}  (build {:.2}s, solve {:.2}s, {} CG iters)",
        rmse(&exact_pred, &test.y),
        exact.report.build_secs,
        exact.report.solve_secs,
        exact.report.cg_iters,
    );

    // 4. Serving surface: freeze β-dependent state once, then predict
    //    allocation-free through the handle (what the TCP server does).
    let handle = model.predictor();
    let mut out = vec![0.0f64; 8];
    handle.predict_into(&test.x[..8 * test.d], &mut out);
    println!("predictor handle: d={} first batch {:?}", handle.dim(), &out[..3]);
    Ok(())
}
